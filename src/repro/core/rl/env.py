"""State, reward, and the incremental environment for the repartitioning DQN.

Paper §IV-D-1: the state concatenates ``2 + 2m`` features — the current MIG
configuration, the time, and the (deadline, average duration) of the first
``m`` jobs in the queue (m = 3, from Alibaba-trace load analysis).  The
naturally continuous features are *binned* to discretize the state space; we
feed the normalized bin indices to the Q-network.

Reward (§IV-D-3): scalarization of energy and tardiness following the ET
metric, accumulated between decision events; the repartitioning cost enters
implicitly through the 4 s blocked-GPU penalty in the simulator.

:class:`RepartitionEnv` is the incremental (``reset()`` / ``step(action)``)
environment over the steppable :class:`~repro.core.engine.SimulationEngine`:
the engine pauses at every §IV-D decision point, the env returns the
observation, and the caller's action resumes the event loop.  Training
(:func:`repro.core.rl.train.train_dqn`) drives this env directly — no
full-run ``decision_hook`` harvesting.
"""

from __future__ import annotations

import dataclasses
import math
from typing import TYPE_CHECKING, Any, Dict, List, Optional, Tuple

import numpy as np

if TYPE_CHECKING:  # pragma: no cover
    from repro.core.metrics import SimResult
    from repro.core.simulator import MIGSimulator
    from repro.fleet.simulator import FleetView

__all__ = [
    "M_JOBS",
    "FEATURE_DIM",
    "FLEET_EXTRA_FEATURES",
    "FLEET_FEATURE_DIM",
    "state_features",
    "fleet_state_features",
    "RewardWeights",
    "RepartitionEnv",
    "make_batched_env",
]

# The paper uses m=3, chosen "based on an analysis of typical GPU loads in
# Alibaba's data center traces" (§IV-D-1).  Our §V-A calibration produces
# deeper peak queues (see EXPERIMENTS.md), so the same load-driven analysis
# selects m=8; the representation stays exactly the paper's 2+2m layout.
M_JOBS = 8
FEATURE_DIM = 2 + 2 * M_JOBS

# Bin edges (minutes) for deadline slack and average duration.
_BIN_EDGES = np.array([0.5, 1.0, 2.0, 5.0, 10.0, 20.0, 40.0, 80.0, 160.0])
_NUM_BINS = len(_BIN_EDGES) + 1  # 10 bins
_TIME_BINS = 48  # half-hour bins over the day


def _bin(v: float) -> int:
    return int(np.searchsorted(_BIN_EDGES, v, side="right"))


def state_features(t: float, sim: "MIGSimulator", m: int = M_JOBS) -> np.ndarray:
    """Normalized feature vector in [0, 1]^(2+2m); missing jobs -> 1.0/0.0."""
    feats: List[float] = []
    feats.append((sim.partition.config_id - 1) / 11.0)
    tod = (t / 60.0) % 24.0
    feats.append(int(tod * 2) % _TIME_BINS / (_TIME_BINS - 1))
    # first m jobs of the QUEUE in EDF order (paper §IV-D-1).  Padding with
    # running jobs would hide queue pressure — the "no job" sentinel pattern
    # is what lets the agent distinguish empty/loaded queues.
    jobs = sim.queue_snapshot()
    for i in range(m):
        if i < len(jobs):
            slack = max(jobs[i].deadline - t, 0.0)
            feats.append(_bin(slack) / (_NUM_BINS - 1))
            feats.append(_bin(jobs[i].mean_duration_all_sizes()) / (_NUM_BINS - 1))
        else:
            feats.append(1.0)  # "no job" sentinel: max slack
            feats.append(0.0)  # zero duration
    return np.asarray(feats, dtype=np.float32)


# Fleet-aware observation: the per-device features above plus two fleet
# signals read off the dispatch-time load trace (repro.fleet.FleetView) —
# this device's share of the fleet backlog, and the normalized fleet-wide
# backlog.  The 2+2m core layout is unchanged, so a single-GPU policy can be
# warm-started by zero-padding and a fleet policy degrades gracefully when
# the fleet context is absent (both extras read 0.0).
FLEET_EXTRA_FEATURES = 2
FLEET_FEATURE_DIM = FEATURE_DIM + FLEET_EXTRA_FEATURES


def fleet_state_features(
    t: float,
    sim: "MIGSimulator",
    device_index: int,
    view: "FleetView | None",
    m: int = M_JOBS,
) -> np.ndarray:
    """Per-device observation inside a fleet, in [0, 1]^FLEET_FEATURE_DIM."""
    base = state_features(t, sim, m)
    if view is None:
        share, pressure = 0.0, 0.0
    else:
        share = view.load_share(device_index, t)
        pressure = view.total_load_norm(t)
    return np.concatenate(
        [base, np.asarray([share, pressure], dtype=np.float32)]
    )


@dataclasses.dataclass(frozen=True)
class RewardWeights:
    """ET-scalarized reward: r = -(a*dE + dTard/m) / (a+1) / scale.

    ``a`` ~ t/(2s) calibrated on the diurnal workload (mean energy s ~ 4.1 kWh
    per day, mean avg-tardiness t ~ 1.2 min).  The tardiness integral is
    normalized by the expected jobs/episode so the summed episode reward
    approximates -ET of the episode (§IV-A uses *average* tardiness).
    """

    a: float = 5e-5
    tardiness_norm: float = 600.0  # ~ expected jobs per diurnal day
    scale: float = 0.01  # keeps |r| O(1) for stable TD learning
    # §IV-D-3: "changing configurations incurs a performance penalty
    # equivalent to the time required for the repartitioning process" (4 s).
    # The stall also occurs physically in the simulator; the explicit term
    # de-noises credit assignment for the switch decision itself.
    switch_penalty_min: float = 4.0 / 60.0

    def interval_reward(self, d_energy_wh: float, d_tardiness: float) -> float:
        y = d_tardiness / self.tardiness_norm
        return -((self.a * d_energy_wh + y) / (self.a + 1.0)) / self.scale

    def switch_penalty(self, jobs_in_system: int) -> float:
        """Reward cost of a repartition: ~4 s of lost service for the whole
        system, expressed in the same normalized-tardiness units."""
        y = self.switch_penalty_min * max(jobs_in_system, 1) / self.tardiness_norm
        return (y / (self.a + 1.0)) / self.scale


class _CadenceTimer:
    """Timer-only pseudo-policy: opens decision points on a fixed clock.

    Interactive engines never call ``decide`` — the timer chain exists only
    to pause :class:`RepartitionEnv` at ``t = k * interval``, the decision
    cadence of the batched env (docs/BATCHED_SIM.md §5).
    """

    def __init__(self, interval_min: float) -> None:
        self.interval = float(interval_min)

    def decide(self, t, sim):  # pragma: no cover - interactive engines skip it
        return None

    def next_timer(self, t: float) -> float:
        return (math.floor(t / self.interval + 1e-9) + 1.0) * self.interval


class RepartitionEnv:
    """Incremental repartitioning environment (Gym-style, §IV-D).

    One episode is one simulated day (or any job stream): ``reset`` builds a
    fresh simulator + interactive :class:`SimulationEngine` and advances to
    the first decision point; ``step(action)`` applies the configuration
    choice, resumes the event loop to the next decision point (or the end of
    the stream), and returns the per-decision reward — the ET-scalarized
    energy/tardiness accumulated over exactly that interval, minus the
    §IV-D-3 switch penalty when the action repartitioned.

    ``step`` returns ``(obs, reward, terminated, truncated, info)``.
    ``truncate_after_min`` / ``max_decisions`` bound an episode early
    (curriculum / wall-clock control): the episode ends with
    ``truncated=True`` and the remaining simulated day is abandoned.

    Actions are config indices ``0..11`` mapping to configurations
    ``1..12`` (the paper's A100 Fig. 1 table); choosing the current
    configuration is a no-op decision.

    ``decision_interval_min`` switches the env from per-event decisions
    (default, the paper's §IV-D cadence) to the fixed clock the batched
    env uses: decisions happen only at ``t = 0, I, 2I, ...`` — event
    decision points in between are auto-held — and an episode ends at the
    first boundary past the last completion.  This is the oracle side of
    the batch-of-1 parity property (tests/test_batched_train.py).
    """

    def __init__(
        self,
        scheduler_name: str = "EDF-SS",
        spec=None,
        scenario: Optional[str] = None,
        scenario_kwargs: Optional[Dict] = None,
        rewards: RewardWeights = RewardWeights(),
        initial_config: int = 2,
        mig_enabled: bool = True,
        truncate_after_min: Optional[float] = None,
        max_decisions: Optional[int] = None,
        m: int = M_JOBS,
        repartition_mode: str = "partial",
        decision_interval_min: Optional[float] = None,
    ) -> None:
        from repro.core.workload import WorkloadSpec

        self.spec = spec or WorkloadSpec()
        self.scenario = scenario
        self.scenario_kwargs = dict(scenario_kwargs or {})
        self.scheduler_name = scheduler_name
        self.rewards = rewards
        self.initial_config = initial_config
        self.mig_enabled = mig_enabled
        # "partial" (slot-placed transitions) or "drain" (legacy full drain);
        # the agent trains against whichever physics it will be evaluated on
        self.repartition_mode = repartition_mode
        self.truncate_after_min = truncate_after_min
        self.max_decisions = max_decisions
        self.m = m
        if decision_interval_min is not None and decision_interval_min <= 0:
            raise ValueError(
                f"decision_interval_min={decision_interval_min} must be positive"
            )
        self.decision_interval_min = decision_interval_min
        self.sim: "MIGSimulator | None" = None
        self.engine = None
        self._prev_energy = 0.0
        self._prev_tard = 0.0
        self._decisions = 0
        self._terminated = True
        self._at_t0 = False

    # ------------------------------------------------------------------
    def reset(self, seed: int = 0, jobs=None) -> np.ndarray:
        """Start a fresh episode; returns the first observation.

        ``jobs`` overrides the generated stream (otherwise the scenario or
        :class:`WorkloadSpec` is drawn with ``seed``).
        """
        from repro.core.engine import SimulationEngine
        from repro.core.scenarios import generate_scenario
        from repro.core.schedulers import make_scheduler
        from repro.core.simulator import MIGSimulator
        from repro.core.workload import generate_jobs

        if jobs is None:
            if self.scenario is not None:
                jobs = generate_scenario(self.scenario, seed=seed, **self.scenario_kwargs)
            else:
                jobs = generate_jobs(self.spec, seed=seed)
        self.sim = MIGSimulator(
            make_scheduler(self.scheduler_name),
            mig_enabled=self.mig_enabled,
            repartition_mode=self.repartition_mode,
        )
        cadence = self.decision_interval_min
        self.engine = SimulationEngine(
            self.sim,
            policy=None if cadence is None else _CadenceTimer(cadence),
            interactive=True,
            initial_config=self.initial_config,
            jobs=jobs,
        )
        self._prev_energy = 0.0
        self._prev_tard = 0.0
        self._decisions = 0
        if cadence is None:
            self._terminated = not self.engine.run_to_decision()
        else:
            # cadence grid starts at t = 0: the first observation/action pair
            # happens before any event, exactly like the batched env's reset
            self._at_t0 = True
            self._terminated = False
        return self._obs()

    def step(self, action: int) -> Tuple[np.ndarray, float, bool, bool, Dict[str, Any]]:
        """Apply ``action`` at the pending decision point and advance."""
        if self.engine is None or self._terminated:
            raise RuntimeError("episode over (or never started); call reset()")
        sim = self.sim
        config_id = int(action) + 1  # actions 0..11 -> configs 1..12
        switched = config_id != sim.partition.config_id
        penalty = (
            self.rewards.switch_penalty(len(sim.active)) if switched else 0.0
        )
        if self._at_t0:
            # cadence mode, first decision: nothing has run yet, so there is
            # no pending interactive decision — apply the switch directly
            self._at_t0 = False
            if switched:
                self.engine.reconfigure(config_id)
        else:
            self.engine.provide_decision(config_id if switched else None)
        self._decisions += 1

        running = (
            self.engine.run_to_decision()
            if self.decision_interval_min is None
            else self._run_to_cadence_decision()
        )
        terminated = not running
        truncated = False
        if running:
            if (
                self.truncate_after_min is not None
                and sim.t >= self.truncate_after_min
            ):
                truncated = True
            if self.max_decisions is not None and self._decisions >= self.max_decisions:
                truncated = True
        self._terminated = terminated or truncated

        d_e = sim.energy_wh - self._prev_energy
        d_t = sim.tardiness_integral - self._prev_tard
        self._prev_energy = sim.energy_wh
        self._prev_tard = sim.tardiness_integral
        reward = self.rewards.interval_reward(d_e, d_t) - penalty

        info = {
            "t": sim.t,
            "switched": switched,
            "config_id": sim.partition.config_id,
            "decisions": self._decisions,
            # same O(1) definition as SimSnapshot/EngineEvent (not the
            # EDF-sorted queue_snapshot(): this runs in the training hot loop)
            "queue_depth": max(len(sim.active) - len(sim.assignment), 0),
        }
        return self._obs(), reward, terminated, truncated, info

    def _run_to_cadence_decision(self) -> bool:
        """Advance to the next ``k * interval`` pause; False when drained.

        Event decision points between boundaries are auto-held (the chosen
        configuration persists — the batched env's held-target semantics).
        A boundary timer firing after the system has fully drained is the
        episode's end, not a decision: the batched env terminates a rollout
        at the first boundary past its last completion, and so does this.
        """
        eng = self.engine
        while eng.run_to_decision():
            if not eng.awaiting_timer:
                eng.provide_decision(None)
                continue
            if (
                eng.arrivals_pending == 0
                and not eng.stream_open
                and not self.sim.active
            ):
                eng.provide_decision(None)
                continue
            return True
        return False

    @property
    def done(self) -> bool:
        """True when no episode is in progress (terminated or truncated)."""
        return self._terminated

    def result(self) -> "SimResult":
        """The finished episode's :class:`SimResult` (terminal episodes only)."""
        if self.engine is None:
            raise RuntimeError("no episode has run")
        return self.engine.result()

    def _obs(self) -> np.ndarray:
        return state_features(self.sim.t, self.sim, self.m)


def make_batched_env(**kwargs):
    """Vectorized counterpart of :class:`RepartitionEnv` (lazy import).

    Returns a :class:`repro.core.batched.BatchedRepartitionEnv` sharing this
    module's feature/reward contract (same ``M_JOBS``, bin tables and
    :class:`RewardWeights`), but stepping ``B`` rollouts per call on the
    batched backend — training scripts collect a whole experience batch per
    decision interval.  Kwargs are forwarded verbatim; see the batched env
    for the cadence/scheduler caveats, and keep using :class:`RepartitionEnv`
    for per-event decisions or non-EDF-FS schedulers.
    """
    from repro.core.batched import BatchedRepartitionEnv

    return BatchedRepartitionEnv(**kwargs)
