"""DQN agent <-> simulator glue.

The agent is a :class:`repro.core.simulator.RepartitionPolicy`: at every
decision event (arrival/completion) it reads the state features, accumulates
the ET-scalarized reward since its previous decision, stores the transition,
optionally trains, and returns the chosen configuration.  Training no
longer goes through this class — :func:`repro.core.rl.train.train_dqn`
drives the incremental :class:`~repro.core.rl.env.RepartitionEnv` directly
— but the agent remains the evaluation-mode policy (``greedy_policy``) the
sweep registry and fleet runs instantiate, and it still collects replay
when used as a live policy.
"""

from __future__ import annotations

import collections
from typing import Optional, TYPE_CHECKING

import numpy as np

from repro.core.rl.dqn import DQNLearner
from repro.core.rl.env import RewardWeights, state_features

if TYPE_CHECKING:  # pragma: no cover
    from repro.core.simulator import MIGSimulator

__all__ = ["NStepAccumulator", "DQNAgent", "greedy_policy"]


class NStepAccumulator:
    """n-step return bookkeeping shared by the agent and the train loop.

    Transitions are buffered until ``n_step`` rewards have accumulated (or
    the episode ends), then emitted into the learner's replay with the
    discounted n-step return and the residual discount ``g`` for the
    bootstrap term.
    """

    def __init__(self, n_step: int, gamma: float) -> None:
        self.n_step = n_step
        self.gamma = gamma
        self._pending: collections.deque = collections.deque()

    def push(self, learner: DQNLearner, s, a, r, s_next, done: bool) -> None:
        """Append ``(s, a, r)``; emit matured transitions into replay."""
        self._pending.append([s, a, r])
        if done:
            # flush everything with the true remaining returns
            while self._pending:
                R, g = 0.0, 1.0
                for (_, _, ri) in self._pending:
                    R += g * ri
                    g *= self.gamma
                s0, a0, _ = self._pending.popleft()
                learner.observe(s0, a0, R, s_next, True, g)
        elif len(self._pending) >= self.n_step:
            R, g = 0.0, 1.0
            for (_, _, ri) in self._pending:
                R += g * ri
                g *= self.gamma
            s0, a0, _ = self._pending.popleft()
            learner.observe(s0, a0, R, s_next, False, g)

    def clear(self) -> None:
        """Drop buffered transitions (episode reset)."""
        self._pending = collections.deque()


class DQNAgent:
    """Training-mode policy: epsilon-greedy actions + replay collection.

    ``decision_interval_min`` puts the agent on a fixed decision cadence:
    it acts only at (or at the first event past) multiples of the interval
    and holds the configuration in between — the decision distribution the
    fused batched trainer (:mod:`repro.core.rl.batched_train`) trains
    under, so cadence-trained policies evaluate on the oracle engine under
    matching semantics.  ``next_timer`` schedules the marks, so the engine
    creates a decision point at each one even when the system idles.
    """

    def __init__(
        self,
        learner: DQNLearner,
        rewards: RewardWeights = RewardWeights(),
        initial_config: int = 2,
        train: bool = True,
        train_steps_per_decision: int = 1,
        guide=None,  # optional policy whose actions warm-start the replay
        decision_interval_min: Optional[float] = None,
    ) -> None:
        if decision_interval_min is not None and decision_interval_min <= 0:
            raise ValueError("decision_interval_min must be positive")
        self.learner = learner
        self.rewards = rewards
        self.initial_config = initial_config
        self.train = train
        self.train_steps = train_steps_per_decision
        self.guide = guide
        self.decision_interval_min = decision_interval_min
        self._next_mark = 0.0
        self.use_guide = False
        self.epsilon = 0.0
        self._prev_state: Optional[np.ndarray] = None
        self._prev_action: Optional[int] = None
        self._prev_energy = 0.0
        self._prev_tard = 0.0
        self._pending_penalty = 0.0
        self._nstep = NStepAccumulator(learner.cfg.n_step, learner.cfg.gamma)
        self.episode_reward = 0.0
        self.losses: list = []

    # -- episode lifecycle -------------------------------------------------
    def begin_episode(self, epsilon: float) -> None:
        self.epsilon = epsilon
        self._next_mark = 0.0
        self._prev_state = None
        self._prev_action = None
        self._prev_energy = 0.0
        self._prev_tard = 0.0
        self._pending_penalty = 0.0
        self._nstep.clear()
        self.episode_reward = 0.0
        self.losses = []

    def _push_nstep(self, s, a, r, s_next, done: bool) -> None:
        self._nstep.push(self.learner, s, a, r, s_next, done)

    def end_episode(self, sim: "MIGSimulator") -> None:
        """Flush the terminal transition (done=True)."""
        if self._prev_state is None:
            return
        r = self._interval_reward(sim)
        self.episode_reward += r
        terminal = state_features(sim.t, sim)
        if self.train:
            self._push_nstep(self._prev_state, self._prev_action, r, terminal, True)
            self.learner.maybe_train(self.train_steps)
        self._prev_state = None

    # -- RepartitionPolicy protocol -----------------------------------------
    def decide(self, t: float, sim: "MIGSimulator") -> Optional[int]:
        if self.decision_interval_min is not None:
            if t < self._next_mark - 1e-9:
                return None  # off-cadence event: hold, no bookkeeping
            interval = self.decision_interval_min
            self._next_mark = (np.floor(t / interval + 1e-9) + 1.0) * interval
        state = state_features(t, sim)
        if self._prev_state is not None:
            r = self._interval_reward(sim)
            self.episode_reward += r
            if self.train:
                self._push_nstep(self._prev_state, self._prev_action, r, state, False)
                loss = self.learner.maybe_train(self.train_steps)
                if loss == loss:  # not NaN
                    self.losses.append(loss)
        if self.use_guide and self.guide is not None:
            choice = self.guide.decide(t, sim)
            action = (choice - 1) if choice is not None else (sim.partition.config_id - 1)
        elif self.train:
            action = self.learner.act(state, self.epsilon)
        else:
            action = self.learner.greedy_action(state)
        self._prev_state = state
        self._prev_action = action
        config_id = action + 1  # actions 0..11 -> configs 1..12
        if config_id != sim.partition.config_id:
            # §IV-D-3 switch penalty, charged to this (s, a) on its next reward
            self._pending_penalty = self.rewards.switch_penalty(len(sim.active))
            return config_id
        return None

    def next_timer(self, t: float) -> Optional[float]:
        if self.decision_interval_min is None:
            return None
        interval = self.decision_interval_min
        return (np.floor(t / interval + 1e-9) + 1.0) * interval

    # -- reward bookkeeping --------------------------------------------------
    def _interval_reward(self, sim: "MIGSimulator") -> float:
        d_e = sim.energy_wh - self._prev_energy
        d_t = sim.tardiness_integral - self._prev_tard
        self._prev_energy = sim.energy_wh
        self._prev_tard = sim.tardiness_integral
        r = self.rewards.interval_reward(d_e, d_t) - self._pending_penalty
        self._pending_penalty = 0.0
        return r


def greedy_policy(
    learner: DQNLearner,
    initial_config: int = 2,
    decision_interval_min: Optional[float] = None,
) -> DQNAgent:
    """Evaluation-mode agent: greedy, no replay writes, no training.

    ``decision_interval_min`` evaluates on the fixed cadence the batched
    trainer trained under (see :class:`DQNAgent`).
    """
    agent = DQNAgent(
        learner,
        train=False,
        initial_config=initial_config,
        decision_interval_min=decision_interval_min,
    )
    agent.begin_episode(epsilon=0.0)
    return agent
