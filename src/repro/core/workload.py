"""Workload generation (paper §V-A).

Jobs arrive by a non-homogeneous Poisson process whose rate follows the
diurnal pattern derived from the Alibaba MLaaS traces (Fig. 5): low overnight,
ramping from ~3:00, peak 5:00–17:00, falling to the overnight level by ~19:00.

Per-job attributes (trace does not include them; §V-A assumptions):
* kind: inference w.p. ``inference_split`` (default 0.8) else training,
* duration ("work", on a 1g slice): inference ~ Exp(rate=3) minutes,
  training ~ U(10, 40) minutes,
* elasticity: one of {linear, capped, sublinear} equally likely;
  capped jobs cap at 2g/3g/4g uniformly; sublinear jobs draw one of the four
  curves uniformly,
* deadline: the paper leaves deadlines unspecified ("user-specified or
  best-effort"); we use ``arrival + slack * dur_on_7g`` with
  slack ~ U(slack_lo, slack_hi) (documented free parameter, DESIGN.md §2).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Callable, Iterator, List, Optional, Sequence

import numpy as np

from repro.core.jobs import (
    SUBLINEAR_CURVES,
    Elasticity,
    Job,
    JobKind,
    LINEAR,
    capped,
)

__all__ = [
    "WorkloadSpec",
    "DIURNAL_RATE_PER_MIN",
    "arrival_rate",
    "generate_jobs",
    "sample_poisson_arrivals",
    "jobs_from_arrivals",
    "DurationSampler",
]

MINUTES_PER_DAY = 24 * 60

# Fig. 5 — arrival rate (jobs/min) by hour of day, linearly interpolated.
# Peak plateau 5:00-17:00 at ~0.5/min, trough overnight ~0.1/min.
DIURNAL_RATE_PER_MIN: Sequence[float] = (
    0.10, 0.08, 0.08, 0.10, 0.22,  # 0..4h (ramp starts ~3-4h)
    0.38, 0.44, 0.48, 0.50, 0.52,  # 5..9h
    0.54, 0.55, 0.54, 0.52, 0.50,  # 10..14h
    0.48, 0.45, 0.40, 0.28, 0.18,  # 15..19h (falls 17-19h)
    0.14, 0.12, 0.10, 0.10,        # 20..23h
)


def arrival_rate(t_min: float, pattern: Sequence[float] = DIURNAL_RATE_PER_MIN) -> float:
    """Interpolated arrival rate (jobs/min) at absolute time ``t_min``."""
    hod = (t_min / 60.0) % 24.0
    lo = int(hod) % 24
    hi = (lo + 1) % 24
    frac = hod - int(hod)
    return pattern[lo] * (1.0 - frac) + pattern[hi] * frac


@dataclasses.dataclass(frozen=True)
class WorkloadSpec:
    """All knobs of the §V-A workload model."""

    horizon_min: float = float(MINUTES_PER_DAY)
    constant_rate: Optional[float] = None  # jobs/min; None => diurnal Fig. 5
    inference_split: float = 0.8
    # §V-A: inference duration "exponentially distributed with a lambda value
    # of 3".  We read this as scale (mean) = 3 minutes: with mean 1/3 min the
    # system never saturates at the paper's arrival rates and tardiness — half
    # of the ET objective — would be identically ~0, contradicting Figs. 7-10.
    inference_mean_min: float = 3.0
    training_lo_min: float = 10.0
    training_hi_min: float = 40.0
    slack_lo: float = 1.2
    slack_hi: float = 4.0
    linear_no_mig_speedup: float = 1.06  # §V-A: full GPU 6% faster for linear jobs

    def rate(self, t_min: float) -> float:
        if self.constant_rate is not None:
            return self.constant_rate
        return arrival_rate(t_min)

    @property
    def peak_rate(self) -> float:
        if self.constant_rate is not None:
            return self.constant_rate
        return max(DIURNAL_RATE_PER_MIN)


def sample_poisson_arrivals(
    horizon_min: float,
    rate_fn: Callable[[float], float],
    lam_max: float,
    rng: np.random.Generator,
) -> List[float]:
    """Thinning sampler for a (non-)homogeneous Poisson process.

    ``rate_fn(t)`` must never exceed ``lam_max`` on [0, horizon_min); the
    returned arrival times are strictly increasing by construction.  The
    scenario library (:mod:`repro.core.scenarios`) reuses this for rate
    patterns the :class:`WorkloadSpec` cannot express (MMPP bursts, scaled
    traces); the RNG draw sequence is identical to the original in-spec
    sampler, so the default diurnal path is bit-stable across the refactor.
    """
    t = 0.0
    out: List[float] = []
    while True:
        t += rng.exponential(1.0 / lam_max)
        if t >= horizon_min:
            break
        if rng.uniform() * lam_max <= rate_fn(t):
            out.append(t)
    return out


def _sample_arrivals(spec: WorkloadSpec, rng: np.random.Generator) -> List[float]:
    return sample_poisson_arrivals(spec.horizon_min, spec.rate, spec.peak_rate, rng)


def _sample_elasticity(rng: np.random.Generator) -> Elasticity:
    u = rng.integers(0, 3)
    if u == 0:
        return LINEAR
    if u == 1:
        return capped(int(rng.choice([2, 3, 4])))
    label = list(SUBLINEAR_CURVES)[int(rng.integers(0, len(SUBLINEAR_CURVES)))]
    return SUBLINEAR_CURVES[label]


#: Optional per-job duration override: ``(kind, rng) -> work`` in 1g-minutes.
#: Used by heavy-tailed scenarios; must perform exactly one bounded draw so
#: job attributes stay deterministic per seed.
DurationSampler = Callable[[JobKind, np.random.Generator], float]


def _sample_work(
    spec: WorkloadSpec,
    kind: JobKind,
    rng: np.random.Generator,
    duration_sampler: Optional[DurationSampler] = None,
) -> float:
    if duration_sampler is not None:
        return duration_sampler(kind, rng)
    if kind is JobKind.INFERENCE:
        # Exp(lambda=3): duration on a 1g slice, minutes.
        work = rng.exponential(spec.inference_mean_min)
        return max(work, 1.0 / 60.0)  # floor at one second
    return rng.uniform(spec.training_lo_min, spec.training_hi_min)


def jobs_from_arrivals(
    spec: WorkloadSpec,
    arrivals: Sequence[float],
    rng: np.random.Generator,
    duration_sampler: Optional[DurationSampler] = None,
) -> List[Job]:
    """Draw per-job attributes (§V-A) for pre-sampled arrival times.

    The RNG call sequence per job — split, duration, elasticity, slack — is
    exactly the legacy ``generate_jobs`` order, so the default path is
    bit-identical across the refactor.  ``duration_sampler`` swaps only the
    duration draw (heavy-tailed scenarios).
    """
    jobs: List[Job] = []
    for i, t in enumerate(arrivals):
        is_inf = rng.uniform() < spec.inference_split
        kind = JobKind.INFERENCE if is_inf else JobKind.TRAINING
        work = _sample_work(spec, kind, rng, duration_sampler)
        elast = _sample_elasticity(rng)
        slack = rng.uniform(spec.slack_lo, spec.slack_hi)
        dur_fastest = elast.duration(work, 7)
        deadline = t + slack * dur_fastest
        jobs.append(
            Job(
                job_id=i,
                kind=kind,
                arrival=t,
                work=work,
                deadline=deadline,
                elasticity=elast,
                speedup_no_mig=spec.linear_no_mig_speedup
                if elast is LINEAR
                else 1.0,
            )
        )
    return jobs


def generate_jobs(
    spec: WorkloadSpec,
    seed: int,
    max_jobs: Optional[int] = None,
) -> List[Job]:
    """Generate one simulation's job queue (sorted by arrival)."""
    rng = np.random.default_rng(seed)
    arrivals = _sample_arrivals(spec, rng)
    if max_jobs is not None:
        arrivals = arrivals[:max_jobs]
    return jobs_from_arrivals(spec, arrivals, rng)
