"""Multi-tenant SLO serving workloads: model configs mapped to MIG classes.

The paper's workload is anonymous batch traffic; a serving fleet instead
carries *tenants* — each a deployed model with a request rate and a latency
SLO.  This module closes the gap between the repo's two previously
unconnected halves: the architecture configs under :mod:`repro.configs`
(gemma3, mixtral, whisper, …) and the MIG slot-placement model of
:mod:`repro.core.slices`.

The mapping is memory-first, the way MIG serving deployments actually pick
instance types (MIG-Serving, arxiv 2109.11067): a model's weight footprint
``param_count × bytes_per_param × overhead`` must fit the slice's memory,
and the smallest of the canonical A100 classes (1g.5gb, 2g.10gb, 4g.20gb,
7g.40gb) that fits is the tenant's *slice class*.  ``bytes_per_param``
encodes the deployed quantization (0.5 = int4, 1.0 = int8, 2.0 = bf16);
the 1.25× overhead reserves KV-cache/activation headroom.

A tenant's requests are capped-elastic at the class width: a request on a
narrower slice runs slowed by ``class/width``, on a wider slice it gains
nothing (the replica is sized for its class).  Each request's latency SLO
is proportional to its own on-class service time, and its deadline is set
to ``arrival + slo`` so EDF-family schedulers order requests by SLO
urgency unmodified.  SLO attainment is evaluated per tenant in
:class:`~repro.core.metrics.TenantSLOStats` (DESIGN.md §9).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Dict, List, Mapping, Tuple

import numpy as np

from repro.configs import get_config
from repro.core.jobs import Elasticity, ElasticityClass, Job, JobKind, capped
from repro.core.workload import (
    DIURNAL_RATE_PER_MIN,
    MINUTES_PER_DAY,
    arrival_rate,
    sample_poisson_arrivals,
)

__all__ = [
    "SLICE_CLASSES",
    "MEMORY_OVERHEAD",
    "TenantSpec",
    "SERVING_MIXES",
    "serving_mix",
    "model_footprint_gb",
    "model_slice_class",
    "class_elasticity",
    "generate_serving_jobs",
]

#: canonical A100 serving classes: (compute slots, memory GB).  The 3g.20gb
#: class is intentionally absent — it shares its memory with 4g.20gb, so
#: memory-first mapping would never choose it.
SLICE_CLASSES: Tuple[Tuple[int, int], ...] = ((1, 5), (2, 10), (4, 20), (7, 40))

#: KV-cache / activation headroom multiplier over the raw weight footprint
MEMORY_OVERHEAD = 1.25

# mean of the Fig. 5 diurnal envelope (jobs/min): tenant rates are specified
# as day-average rates and modulated by the normalized envelope, so a
# tenant's expected request count over a day is rate_per_min × horizon
_DIURNAL_MEAN = sum(DIURNAL_RATE_PER_MIN) / len(DIURNAL_RATE_PER_MIN)


def model_footprint_gb(model: str, bytes_per_param: float) -> float:
    """Serving memory footprint of a deployed model (GB, with overhead)."""
    params = get_config(model).param_count()
    return params * bytes_per_param * MEMORY_OVERHEAD / 1e9


def model_slice_class(model: str, bytes_per_param: float) -> Tuple[int, int]:
    """Smallest canonical (slots, memory_gb) class that fits the model."""
    need = model_footprint_gb(model, bytes_per_param)
    for slots, mem in SLICE_CLASSES:
        if need <= mem:
            return slots, mem
    raise ValueError(
        f"model {model!r} needs {need:.1f}GB at {bytes_per_param} B/param; "
        f"largest serving class is {SLICE_CLASSES[-1][1]}GB — quantize harder"
    )


@functools.lru_cache(maxsize=None)
def class_elasticity(slots: int) -> Elasticity:
    """Capped elasticity at the tenant's slice-class width.

    The paper's :func:`~repro.core.jobs.capped` only admits the §III-B caps
    {2, 3, 4}; serving classes also need 1 and 7, built directly here with
    the same label convention.  Memoized so every request of a class shares
    one :class:`Elasticity` instance — job streams regenerated for the same
    cell then compare equal (the throughput curve is a lambda; distinct
    instances never would).
    """
    if slots in (2, 3, 4):
        return capped(slots)
    return Elasticity(
        ElasticityClass.CAPPED,
        f"capped@{slots}g",
        lambda k, c=slots: min(k, float(c)),
        cap=slots,
    )


@dataclasses.dataclass(frozen=True)
class TenantSpec:
    """One serving tenant: a deployed model with traffic and SLO terms.

    ``rate_per_min`` is the tenant's day-average request rate at
    ``load_scale=1`` (the diurnal envelope modulates it around that mean).
    ``mean_service_min`` is the mean request service time *on the tenant's
    slice class*; a request's work is ``service × class_slots`` 1g-minutes.
    ``slo_scale`` multiplies each request's own on-class service time into
    its latency SLO — 2.0 means "finish within 2× your ideal runtime",
    tolerating a sub-class slice or a short queue but not both.
    """

    name: str
    model: str
    bytes_per_param: float
    rate_per_min: float
    mean_service_min: float
    slo_scale: float

    @property
    def slice_class(self) -> Tuple[int, int]:
        return model_slice_class(self.model, self.bytes_per_param)

    @property
    def demand_slots(self) -> int:
        return self.slice_class[0]


#: named tenant mixes for the ``multi-tenant-serving`` scenario.  Rates are
#: normalized so "balanced" offers ~7 1g-min of work per minute at
#: load_scale=1 — about one A100 — and fleet cells scale up from there.
SERVING_MIXES: Dict[str, Tuple[TenantSpec, ...]] = {
    "balanced": (
        TenantSpec("asr-whisper-base", "whisper-base", 1.0, 1.00, 0.5, 4.0),
        TenantSpec("chat-gemma3-1b", "gemma3-1b", 1.0, 0.70, 1.5, 3.0),
        TenantSpec("agent-gemma3-12b", "gemma3-12b", 1.0, 0.22, 3.0, 2.0),
        TenantSpec("synth-mixtral-8x7b", "mixtral-8x7b", 0.5, 0.08, 5.0, 2.0),
    ),
    "small-heavy": (
        TenantSpec("asr-whisper-base", "whisper-base", 1.0, 1.60, 0.5, 4.0),
        TenantSpec("chat-gemma3-1b", "gemma3-1b", 1.0, 1.20, 1.5, 3.0),
        TenantSpec("embed-stablelm-3b", "stablelm-3b", 1.0, 0.80, 2.0, 3.0),
        TenantSpec("agent-gemma3-12b-int4", "gemma3-12b", 0.5, 0.30, 2.5, 2.0),
    ),
    "large-heavy": (
        TenantSpec("chat-gemma3-1b", "gemma3-1b", 1.0, 0.50, 1.5, 3.0),
        TenantSpec("agent-gemma3-12b", "gemma3-12b", 1.0, 0.30, 3.0, 2.0),
        TenantSpec("synth-mixtral-8x7b", "mixtral-8x7b", 0.5, 0.12, 5.0, 2.0),
    ),
}


def serving_mix(name: str) -> Tuple[TenantSpec, ...]:
    """Look up a named tenant mix."""
    try:
        return SERVING_MIXES[name]
    except KeyError as e:
        raise KeyError(
            f"unknown serving mix {name!r}; registered: {sorted(SERVING_MIXES)}"
        ) from e


def generate_serving_jobs(
    seed: int,
    mix: str = "balanced",
    load_scale: float = 1.0,
    slo_mult: float = 1.0,
    horizon_min: float = float(MINUTES_PER_DAY),
) -> List[Job]:
    """Deterministic multi-tenant request stream, sorted by arrival.

    Each tenant draws from an independent RNG stream seeded by
    ``(seed, tenant index)``, so adding a tenant to a mix never perturbs
    the others' draws.  Requests are Poisson over the normalized diurnal
    envelope at the tenant's day-average rate, with exponential on-class
    service times; ``slo_min = slo_scale × slo_mult × service`` and
    ``deadline = arrival + slo_min``.
    """
    tenants = serving_mix(mix)
    all_jobs: List[Job] = []
    for ti, ten in enumerate(tenants):
        rng = np.random.default_rng([seed, 0x5E21, ti])
        mean_rate = ten.rate_per_min * load_scale
        lam_max = mean_rate * max(DIURNAL_RATE_PER_MIN) / _DIURNAL_MEAN

        def rate(t: float, r: float = mean_rate) -> float:
            return r * arrival_rate(t) / _DIURNAL_MEAN

        arrivals = sample_poisson_arrivals(horizon_min, rate, lam_max, rng)
        demand = ten.demand_slots
        elasticity = class_elasticity(demand)
        for a in arrivals:
            service = max(rng.exponential(ten.mean_service_min), 1.0 / 60.0)
            slo = ten.slo_scale * slo_mult * service
            all_jobs.append(
                Job(
                    job_id=0,  # renumbered after the merge sort below
                    kind=JobKind.INFERENCE,
                    arrival=a,
                    work=service * demand,
                    deadline=a + slo,
                    elasticity=elasticity,
                    tenant=ten.name,
                    slo_min=slo,
                )
            )
    all_jobs.sort(key=lambda j: (j.arrival, j.tenant or ""))
    for i, j in enumerate(all_jobs):
        j.job_id = i
    return all_jobs


def _register() -> None:
    # deferred to dodge the scenarios <-> serving import cycle: scenarios
    # imports this module at its bottom, after the registry exists
    from repro.core.scenarios import register_scenario

    @register_scenario(
        "multi-tenant-serving",
        "tenant request streams with latency SLOs; models mapped to MIG "
        "slice classes by memory footprint (DESIGN.md §9)",
        mix="balanced",
        load_scale=1.0,
        slo_mult=1.0,
        horizon_min=float(MINUTES_PER_DAY),
    )
    def _multi_tenant_serving(
        seed: int, mix: str, load_scale: float, slo_mult: float, horizon_min: float
    ) -> List[Job]:
        return generate_serving_jobs(seed, mix, load_scale, slo_mult, horizon_min)


_register()
