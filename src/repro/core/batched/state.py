"""Padded batch containers for the batched backend (numpy, jax-free).

:class:`BatchedJobs` freezes a ragged list of per-rollout job lists into
rectangular ``(B, J)`` arrays — ``J`` is the max job count rounded up to a
padding multiple so differently-sized workloads share one compiled program.
Padding rows carry ``arrival = +inf`` and ``remaining = 0`` so they are
never eligible and never accrue anything.

Elasticity curves are pre-evaluated into ``rate_by_slots[b, j, k]`` (the
work-deplete rate of job ``j`` on a ``k``-slot slice, with the cell's
``mig_enabled`` speedup folded in), turning the per-job Python callables of
:mod:`repro.core.jobs` into one gather inside the scan.

:class:`BatchedResult` is the host-side mirror of the accumulator carry:
it converts back to the oracle's :class:`repro.core.metrics.SimResult` /
sweep result-dict vocabulary so downstream aggregation (ET tables, grids,
baselines) is backend-agnostic.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Sequence

import numpy as np

from repro.core.jobs import Job
from repro.core.metrics import SimResult

__all__ = ["BatchedJobs", "BatchedResult", "PAD_MULTIPLE"]

#: job-axis padding multiple: every batch pads ``J`` up to this, so the
#: jitted scan recompiles only when workloads cross a 32-job boundary.
PAD_MULTIPLE = 32

_TARDY_EPS = 1e-9


@dataclasses.dataclass(frozen=True)
class BatchedJobs:
    """Rectangular ``(B, J)`` job arrays for a batch of rollouts.

    ``rate_by_slots`` has shape ``(B, J, K)`` with ``K = max_slots + 1``;
    level 0 is always 0.0 (an unassigned job depletes nothing).  ``valid``
    masks padding rows; ``num_jobs`` is the true per-rollout job count.
    """

    arrival: np.ndarray  # (B, J) float32, +inf padded
    deadline: np.ndarray  # (B, J) float32, +inf padded
    work: np.ndarray  # (B, J) float32, 0 padded
    rate_by_slots: np.ndarray  # (B, J, K) float32, 0 padded
    valid: np.ndarray  # (B, J) bool
    num_jobs: np.ndarray  # (B,) int32
    edf_order: np.ndarray  # (B, J) int32 job indices sorted by (deadline, id)

    @property
    def batch(self) -> int:
        """``B`` — number of rollouts advancing lock-step."""
        return int(self.arrival.shape[0])

    @property
    def padded_jobs(self) -> int:
        """``J`` — padded job capacity per rollout."""
        return int(self.arrival.shape[1])

    @classmethod
    def from_job_lists(
        cls,
        job_lists: Sequence[Sequence[Job]],
        *,
        max_slots: int,
        mig_enabled: bool = True,
        pad_multiple: int = PAD_MULTIPLE,
        min_jobs: int = 1,
    ) -> "BatchedJobs":
        """Pad ``B`` ragged job lists into one rectangular container.

        Jobs must be fresh (``remaining == work``); the batched backend owns
        depletion state internally.  ``max_slots`` sizes the rate table's
        slot axis (use ``DeviceTables.max_slots``).  ``min_jobs`` floors the
        padded job axis — callers that run many batches through one compiled
        program (the RL trainer's round loop) pass the global maximum so
        every round shares one shape.
        """
        B = len(job_lists)
        if B == 0:
            raise ValueError("empty batch")
        longest = max((len(js) for js in job_lists), default=0)
        want = max(longest, int(min_jobs), 1)
        J = max(pad_multiple, -(-want // pad_multiple) * pad_multiple)
        K = max_slots + 1

        arrival = np.full((B, J), np.inf, dtype=np.float32)
        deadline = np.full((B, J), np.inf, dtype=np.float32)
        work = np.zeros((B, J), dtype=np.float32)
        rates = np.zeros((B, J, K), dtype=np.float32)
        valid = np.zeros((B, J), dtype=bool)
        num_jobs = np.zeros((B,), dtype=np.int32)

        for b, jobs in enumerate(job_lists):
            num_jobs[b] = len(jobs)
            for j, job in enumerate(jobs):
                if abs(job.remaining - job.work) > 1e-9:
                    raise ValueError(
                        f"rollout {b} job {job.job_id}: partially-run jobs "
                        "cannot enter a batched rollout"
                    )
                arrival[b, j] = job.arrival
                deadline[b, j] = job.deadline
                work[b, j] = job.work
                valid[b, j] = True
                for k in range(1, K):
                    rates[b, j, k] = job.rate_on(float(k), mig_enabled)
        # deadlines are static, so EDF order is too: pre-sorting here turns
        # the per-step priority selection into a cumsum over a boolean mask
        # (stable sort keeps the oracle's (deadline, arrival, job_id)
        # tie-break, since job ids are arrival-ordered)
        edf_order = np.argsort(deadline, axis=1, kind="stable").astype(np.int32)
        return cls(
            arrival=arrival,
            deadline=deadline,
            work=work,
            rate_by_slots=rates,
            valid=valid,
            num_jobs=num_jobs,
            edf_order=edf_order,
        )


@dataclasses.dataclass(frozen=True)
class BatchedResult:
    """Per-rollout aggregates of one :func:`simulate_batch` call (numpy).

    Mirrors the oracle's :class:`SimResult` fields plus the side channels the
    sweep layer records (utilization histogram); ``completion`` keeps the
    exact per-job finish times (``+inf`` for padding rows).
    """

    energy_wh: np.ndarray  # (B,) float64
    tardiness_integral: np.ndarray  # (B,) float64
    busy_slot_minutes: np.ndarray  # (B,) float64
    preemptions: np.ndarray  # (B,) int64
    repartitions: np.ndarray  # (B,) int64
    completion: np.ndarray  # (B, J) float64, +inf on padding
    deadline: np.ndarray  # (B, J) float64
    valid: np.ndarray  # (B, J) bool
    num_jobs: np.ndarray  # (B,) int64
    makespan_min: np.ndarray  # (B,) float64
    util_histogram: np.ndarray  # (B, K) float64 minutes at each busy level

    @property
    def batch(self) -> int:
        """``B`` — rollout count."""
        return int(self.energy_wh.shape[0])

    def _tardiness(self, b: int) -> np.ndarray:
        mask = self.valid[b]
        tardy = self.completion[b, mask] - self.deadline[b, mask]
        return np.maximum(tardy, 0.0)

    def to_sim_result(self, b: int) -> SimResult:
        """Rollout ``b`` as the oracle's :class:`SimResult`."""
        tardy = self._tardiness(b)
        n = int(self.num_jobs[b])
        total = float(tardy.sum())
        return SimResult(
            energy_wh=float(self.energy_wh[b]),
            avg_tardiness=total / max(n, 1),
            num_jobs=n,
            total_tardiness=total,
            preemptions=int(self.preemptions[b]),
            repartitions=int(self.repartitions[b]),
            max_tardiness=float(tardy.max()) if tardy.size else 0.0,
            deadline_misses=int((tardy > _TARDY_EPS).sum()),
            busy_slot_minutes=float(self.busy_slot_minutes[b]),
            extra={
                "makespan_min": float(self.makespan_min[b]),
                "tardiness_integral": float(self.tardiness_integral[b]),
            },
        )

    def to_sim_results(self) -> List[SimResult]:
        """All rollouts as :class:`SimResult`, batch order preserved."""
        return [self.to_sim_result(b) for b in range(self.batch)]

    def to_result_dicts(self) -> List[Dict[str, Any]]:
        """Sweep-layer result dicts (the ``run_cell`` vocabulary).

        ``config_trace`` is empty — like fleet cells, batched cells do not
        record the per-rollout switch trace (documented in docs/BATCHED_SIM.md).
        """
        out: List[Dict[str, Any]] = []
        for b, res in enumerate(self.to_sim_results()):
            hist = {
                str(k): float(v)
                for k, v in enumerate(self.util_histogram[b])
                if v > 0.0
            }
            out.append(
                {
                    "energy_wh": res.energy_wh,
                    "avg_tardiness": res.avg_tardiness,
                    "num_jobs": res.num_jobs,
                    "total_tardiness": res.total_tardiness,
                    "preemptions": res.preemptions,
                    "repartitions": res.repartitions,
                    "max_tardiness": res.max_tardiness,
                    "deadline_misses": res.deadline_misses,
                    "busy_slot_minutes": res.busy_slot_minutes,
                    "extra": dict(res.extra),
                    "util_histogram": hist,
                    "config_trace": [],
                }
            )
        return out
