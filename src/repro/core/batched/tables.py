"""Padded device tables for the batched backend (numpy, jax-free).

The batched simulator cannot chase Python objects at trace time, so this
module flattens the slot-placement model of :mod:`repro.core.slices` into
dense integer tables, padded to the device's maximum slice count ``S``:

* ``slice_slots[c, s]`` — compute size of slice ``s`` under config index
  ``c`` (0 beyond ``num_slices[c]``);
* ``slice_rank[c, r]`` — the slice index holding fastest-first rank ``r``,
  replicating :meth:`repro.core.slices.Partition.sorted_indices` including
  its stable tie-break (−1 beyond ``num_slices[c]``);
* ``old_to_new[a, b, s]`` — where slice ``s`` of config index ``a`` lands
  after a *partial* repartition to config index ``b`` (−1 = destroyed),
  computed by :func:`repro.core.slices.transition` for every config pair.
  The drain model is the all-(−1) degenerate case and needs no table.

Everything here is plain numpy so sweep workers and tests can build tables
without importing jax; :mod:`repro.core.batched.backend` converts them to
device arrays once per simulation.
"""

from __future__ import annotations

import dataclasses
from typing import Mapping, Optional, Sequence

import numpy as np

from repro.core.power import A100_250W, PowerModel
from repro.core.simulator import REPARTITION_PENALTY_MIN
from repro.core.slices import MIG_CONFIGS, Partition, transition

__all__ = ["DeviceTables", "build_tables"]


@dataclasses.dataclass(frozen=True)
class DeviceTables:
    """Dense, padded view of one device's partition table + power curve.

    Shapes use ``C`` = number of configurations, ``S`` = max slices of any
    configuration, ``K`` = ``max_slots + 1`` (busy-slot levels 0..max_slots).
    All arrays are read-only numpy; see the module docstring for semantics.
    """

    config_ids: np.ndarray  # (C,) int32, ascending config ids
    num_slices: np.ndarray  # (C,) int32
    slice_slots: np.ndarray  # (C, S) int32, 0-padded
    slice_rank: np.ndarray  # (C, S) int32, fastest-first, -1-padded
    old_to_new: np.ndarray  # (C, C, S) int32, -1 = destroyed
    watts_by_busy: np.ndarray  # (K,) float32
    max_slots: int
    penalty_min: float

    @property
    def num_configs(self) -> int:
        """``C`` — how many configurations the device exposes."""
        return int(self.config_ids.shape[0])

    @property
    def max_slices(self) -> int:
        """``S`` — the padded per-config slice capacity."""
        return int(self.slice_slots.shape[1])

    def index_of(self, config_id: int) -> int:
        """Dense config index for a 1-based configuration id."""
        idx = int(np.searchsorted(self.config_ids, config_id))
        if idx >= len(self.config_ids) or self.config_ids[idx] != config_id:
            raise KeyError(
                f"config {config_id} not in table (valid ids "
                f"{self.config_ids.tolist()})"
            )
        return idx


def build_tables(
    configs: Optional[Mapping[int, Partition]] = None,
    power: PowerModel = A100_250W,
    penalty_min: float = REPARTITION_PENALTY_MIN,
) -> DeviceTables:
    """Flatten a partition table + power model into :class:`DeviceTables`.

    ``configs`` defaults to the paper's A100 Fig. 1 table.  The power curve
    must cover busy levels up to the largest configuration footprint (the
    same invariant :class:`repro.core.power.PowerModel` enforces on lookup).
    """
    table = dict(MIG_CONFIGS if configs is None else configs)
    ids = sorted(table)
    parts: Sequence[Partition] = [table[i] for i in ids]
    C = len(parts)
    S = max(p.num_slices for p in parts)
    max_slots = max(p.starts[i] + p.slices[i].slots
                    for p in parts for i in range(p.num_slices))

    num_slices = np.array([p.num_slices for p in parts], dtype=np.int32)
    slice_slots = np.zeros((C, S), dtype=np.int32)
    slice_rank = np.full((C, S), -1, dtype=np.int32)
    for c, p in enumerate(parts):
        for s, st in enumerate(p.slices):
            slice_slots[c, s] = st.slots
        ranked = p.sorted_indices(descending=True)
        slice_rank[c, : len(ranked)] = np.array(ranked, dtype=np.int32)

    old_to_new = np.full((C, C, S), -1, dtype=np.int32)
    for a, pa in enumerate(parts):
        for b, pb in enumerate(parts):
            surv = transition(pa, pb).survivor_map
            for old_idx, new_idx in surv.items():
                old_to_new[a, b, old_idx] = new_idx

    watts = np.asarray(
        [power.power_watts(float(k)) for k in range(max_slots + 1)],
        dtype=np.float32,
    )

    for arr in (num_slices, slice_slots, slice_rank, old_to_new, watts):
        arr.setflags(write=False)
    config_ids = np.asarray(ids, dtype=np.int32)
    config_ids.setflags(write=False)
    return DeviceTables(
        config_ids=config_ids,
        num_slices=num_slices,
        slice_slots=slice_slots,
        slice_rank=slice_rank,
        old_to_new=old_to_new,
        watts_by_busy=watts,
        max_slots=int(max_slots),
        penalty_min=float(penalty_min),
    )
