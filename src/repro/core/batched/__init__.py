"""Batched fixed-timestep simulation backend (vmap/scan rollouts).

The throughput half of the repo's two-backend contract (docs/BATCHED_SIM.md,
docs/ARCHITECTURE.md): the event-driven :class:`repro.core.engine.
SimulationEngine` remains the bit-exact oracle; this package advances many
(seed × scenario × config) rollouts lock-step as JAX arrays and reproduces
the oracle's ET/energy/tardiness aggregates within documented tolerances.

Public surface:

* :func:`build_tables` / :class:`DeviceTables` — the slot-placement model
  flattened to padded arrays (numpy, jax-free);
* :class:`BatchedJobs` / :class:`BatchedResult` — padded batch containers
  and the SimResult-compatible aggregates;
* :func:`compile_policy` / :class:`BatchedPolicy` — oracle policies
  compiled to per-rollout target arrays (static/nomig/daynight);
* :func:`simulate_batch` — run a batch to completion (jax imported here);
* :class:`BatchedRepartitionEnv` — the vectorized RL environment.

Importing the package is jax-free; jax loads on the first simulated step.
"""

from repro.core.batched.backend import (
    DEFAULT_CHUNK_STEPS,
    DEFAULT_DT_MIN,
    RolloutState,
    simulate_batch,
)
from repro.core.batched.env import BatchedRepartitionEnv
from repro.core.batched.policies import (
    BatchedPolicy,
    UnsupportedPolicyError,
    compile_policy,
    held_policy,
)
from repro.core.batched.state import BatchedJobs, BatchedResult, PAD_MULTIPLE
from repro.core.batched.tables import DeviceTables, build_tables

__all__ = [
    "DEFAULT_CHUNK_STEPS",
    "DEFAULT_DT_MIN",
    "PAD_MULTIPLE",
    "BatchedJobs",
    "BatchedPolicy",
    "BatchedRepartitionEnv",
    "BatchedResult",
    "DeviceTables",
    "RolloutState",
    "UnsupportedPolicyError",
    "build_tables",
    "compile_policy",
    "held_policy",
    "simulate_batch",
]
