"""Fixed-timestep batched rollouts: ``vmap`` over the batch, ``scan`` over time.

This is the throughput backend of the two-backend contract
(docs/BATCHED_SIM.md): the event-driven :class:`repro.core.engine.
SimulationEngine` stays the bit-exact oracle, while this module advances many
independent rollouts lock-step on a ``dt_min`` time grid as one JAX program.

Per step (see docs/BATCHED_SIM.md §3 for the full semantics):

1. an elapsed repartition completes (survivors remapped via the
   ``old_to_new`` table, pending config installed);
2. the compiled policy may start a repartition — jobs on non-surviving
   slices are preempted, the §IV-D-3 stall timer starts;
3. EDF-FS reassigns eligible jobs to fastest-first slices (frozen while a
   repartition is in flight), preemptions counted by diffing assignments;
4. the step advances ``dt``: work depletes, completions land at their exact
   sub-step time, tardiness/energy/busy accumulators integrate over the
   step (energy uses the power curve at the step's time-averaged busy).

A rollout's accounting stops at its ``stop_time`` — the oracle's end-of-run
point (last completion for static policies; the one post-drain boundary
timer a DayNight run still fires).  The host driver re-invokes one jitted
chunk until every rollout has passed its stop time, so wall-clock cost
scales with the slowest rollout, not a global horizon guess.

Numerics are float32 throughout (JAX CPU default); the documented
oracle-agreement tolerances in docs/BATCHED_SIM.md §4 absorb both the ``dt``
discretization and float32 accumulation.
"""

from __future__ import annotations

import functools
from typing import Any, Dict, NamedTuple, Optional

import numpy as np

from repro.core.batched.policies import BatchedPolicy
from repro.core.batched.state import BatchedJobs, BatchedResult
from repro.core.batched.tables import DeviceTables, build_tables
from repro.core.simulator import REPARTITION_MODES

__all__ = [
    "DEFAULT_DT_MIN",
    "DEFAULT_CHUNK_STEPS",
    "RolloutState",
    "device_constants",
    "init_state",
    "make_step_fn",
    "run_steps",
    "simulate_batch",
    "result_of",
]

#: default time-grid resolution (minutes). Must divide 60 so the DayNight
#: boundaries (multiples of 60 min) land exactly on grid points.
DEFAULT_DT_MIN = 0.5

#: steps per jitted scan chunk; the host loop re-invokes the same compiled
#: chunk until every rollout passes its stop time.
DEFAULT_CHUNK_STEPS = 512

_DAY = 24 * 60.0
# float32 grid: time comparisons tolerate ~1e-6 min, work ~1e-6 1g-minutes
_T_EPS = 1e-6
_W_EPS = 1e-6
#: job-axis block size for the two-level EDF rank search; J must be a
#: multiple of this (BatchedJobs pads to PAD_MULTIPLE == _BLOCK).
_BLOCK = 32


class RolloutState(NamedTuple):
    """The scan carry: every mutable per-rollout quantity, batch-leading.

    ``cfg``/``pending`` are dense config indices (``pending != cfg`` means a
    repartition is in flight); ``stop_time`` is ``+inf`` until the rollout's
    accounting endpoint is known.  Accumulators mirror the oracle's
    :class:`~repro.core.simulator.MIGSimulator` counters.
    """

    remaining: Any  # (B, J) f32 work left
    completion: Any  # (B, J) f32, +inf until completed
    slice_job: Any  # (B, S) i32 job index running on each slice, -1 = idle
    cfg: Any  # (B,) i32 dense config index
    pending: Any  # (B,) i32 repartition target (== cfg when idle)
    stall_left: Any  # (B,) f32 minutes of stall remaining
    stop_time: Any  # (B,) f32 accounting endpoint, +inf while running
    energy_wh: Any  # (B,) f32
    tardiness_integral: Any  # (B,) f32
    busy_slot_minutes: Any  # (B,) f32
    preemptions: Any  # (B,) i32
    repartitions: Any  # (B,) i32
    util_hist: Any  # (B, K) f32 minutes at each integer busy level


def device_constants(
    tables: DeviceTables, repartition_mode: str = "partial"
) -> Dict[str, Any]:
    """Device-side copies of the tables one ``simulate_batch`` run needs.

    Drain mode degenerates the survivor table to all-(−1): every slice is
    destroyed on any switch, exactly the legacy full-drain model.
    """
    import jax.numpy as jnp

    if repartition_mode not in REPARTITION_MODES:
        raise ValueError(
            f"unknown repartition_mode {repartition_mode!r}; valid: "
            f"{REPARTITION_MODES}"
        )
    o2n = tables.old_to_new
    if repartition_mode == "drain":
        o2n = np.full_like(o2n, -1)
    return {
        "slice_slots": jnp.asarray(tables.slice_slots),
        "slice_rank": jnp.asarray(tables.slice_rank),
        "num_slices": jnp.asarray(tables.num_slices),
        "old_to_new": jnp.asarray(o2n),
        "watts": jnp.asarray(tables.watts_by_busy),
    }


def init_state(jobs: BatchedJobs, initial_idx: np.ndarray) -> RolloutState:
    """Fresh carry at ``t = 0`` with per-rollout initial config indices.

    Rollouts with no jobs (or only zero-work jobs) are already "finished":
    their ``stop_time`` is 0 and zero-work jobs complete at their arrival,
    matching the oracle's immediate-completion sweep.
    """
    import jax.numpy as jnp

    B, J = jobs.arrival.shape
    K = jobs.rate_by_slots.shape[2]
    S = K - 1  # DeviceTables pads slices to max_slots
    zero_work = jobs.valid & (jobs.work <= _W_EPS)
    completion0 = np.where(zero_work, jobs.arrival, np.inf).astype(np.float32)
    has_work = (jobs.valid & (jobs.work > _W_EPS)).any(axis=1)
    stop0 = np.where(has_work, np.inf, 0.0).astype(np.float32)
    init = np.asarray(initial_idx, dtype=np.int32)
    if init.shape != (B,):
        raise ValueError(f"initial_idx shape {init.shape} != ({B},)")
    f32 = jnp.float32
    return RolloutState(
        remaining=jnp.asarray(jobs.work, dtype=f32),
        completion=jnp.asarray(completion0),
        slice_job=jnp.full((B, S), -1, dtype=jnp.int32),
        cfg=jnp.asarray(init),
        pending=jnp.asarray(init),
        stall_left=jnp.zeros((B,), dtype=f32),
        stop_time=jnp.asarray(stop0),
        energy_wh=jnp.zeros((B,), dtype=f32),
        tardiness_integral=jnp.zeros((B,), dtype=f32),
        busy_slot_minutes=jnp.zeros((B,), dtype=f32),
        preemptions=jnp.zeros((B,), dtype=jnp.int32),
        repartitions=jnp.zeros((B,), dtype=jnp.int32),
        util_hist=jnp.zeros((B, K), dtype=f32),
    )


@functools.lru_cache(maxsize=None)
def make_step_fn(kind: str, dt: float, penalty: float,
                 day_start: float, day_end: float):
    """Build (and cache) the per-(rollout, step) physics function.

    This is the single source of the batched step semantics: both the
    simulation chunk below and the fused RL training scan
    (:mod:`repro.core.rl.batched_train`) vmap exactly this function, so an
    agent trains against the very physics its rollouts are evaluated on.
    The cache key mirrors :func:`_chunk_fn` minus the step count.
    """
    import jax.numpy as jnp

    def step_one(carry, t, arrival, deadline, rates, valid, dorder,
                 primary, secondary,
                 slice_slots, slice_rank, num_slices, o2n, watts):
        # one rollout, one step.  All per-job state is (J,); everything about
        # the <= S running jobs lives in (S,) lanes keyed by slice index
        # (``slice_job``), so the only O(J) work per step is a handful of
        # fused elementwise ops plus one cumsum — no sorts (EDF order is
        # static and pre-computed in ``dorder``).
        (remaining, completion, slice_job, cfg, pending, stall_left,
         stop_time, energy, tard, busy_min, pre, rep, hist) = carry
        S = slice_slots.shape[1]
        J = remaining.shape[0]
        max_slots = watts.shape[0] - 1
        i32 = jnp.int32

        # -- 1. an elapsed repartition completes ------------------------
        in_flight = pending != cfg
        finish = in_flight & (stall_left <= _T_EPS)
        surv = o2n[cfg, pending]  # (S,) old->new survivor indices
        occ = slice_job >= 0
        keep = finish & occ & (surv >= 0)
        remapped = jnp.full((S,), -1, i32).at[
            jnp.where(keep, surv, S)
        ].set(jnp.where(keep, slice_job, -1), mode="drop")
        slice_job = jnp.where(finish, remapped, slice_job)
        cfg = jnp.where(finish, pending, cfg)

        # -- 2. policy decision (never mid-flight, never past stop) -----
        in_flight = pending != cfg
        if kind == "daynight":
            tod = jnp.mod(t, _DAY)
            is_day = (tod >= day_start) & (tod < day_end)
            target = jnp.where(is_day, primary, secondary)
        else:
            target = primary
        want = (~in_flight) & (t <= stop_time + _T_EPS) & (target != cfg)
        surv_t = o2n[cfg, target]  # (S,)
        kill = want & (slice_job >= 0) & (surv_t < 0)
        pre = pre + jnp.sum(kill).astype(i32)
        slice_job = jnp.where(kill, -1, slice_job)
        pending = jnp.where(want, target, pending)
        stall_left = jnp.where(want, jnp.float32(penalty), stall_left)
        rep = rep + want.astype(i32)
        in_flight = pending != cfg

        # -- 3. EDF-FS reassignment (frozen while repartitioning) -------
        # first 2S in-system jobs in EDF order: permute the in-system mask
        # by the static deadline order, then find the first 2S set bits with
        # a two-level rank search — per-block popcounts, a short cumsum over
        # blocks, and an intra-block scan only for the <= 2S hit blocks.
        # (A full-J cumsum or an O(J)-update scatter here dominates the
        # whole step on CPU XLA.)
        insys = (arrival <= t + _T_EPS) & (remaining > _W_EPS) & valid
        m = insys[dorder]
        NB = J // _BLOCK
        mb = m.reshape(NB, _BLOCK)
        bc = jnp.cumsum(jnp.sum(mb, axis=1, dtype=i32))  # (NB,)
        ranks = jnp.arange(1, 2 * S + 1, dtype=i32)
        blk = jnp.searchsorted(bc, ranks)  # first block with cum >= rank
        blkc = jnp.clip(blk, 0, NB - 1)
        prev = jnp.where(blk > 0, bc[jnp.maximum(blk - 1, 0)], 0)
        sub = mb[blkc]  # (2S, BLOCK)
        sc = jnp.cumsum(sub.astype(i32), axis=1)
        need = (ranks - prev)[:, None]
        off = jnp.argmax(sub & (sc == need), axis=1)
        pos = blkc * _BLOCK + off
        cand = jnp.where(blk < NB, dorder[pos], J)
        ranked = slice_rank[cfg]  # (S,) slice ids fastest-first, -1 padded
        rv = (ranked >= 0) & (cand[:S] < J)
        proposed = jnp.full((S,), -1, i32).at[
            jnp.where(rv, ranked, S)
        ].set(jnp.where(rv, cand[:S], -1), mode="drop")
        new_sj = jnp.where(in_flight, slice_job, proposed)
        moved = (slice_job >= 0) & (new_sj != slice_job) & (~in_flight)
        pre = pre + jnp.sum(moved).astype(i32)
        slice_job = new_sj

        # -- 4. advance dt ----------------------------------------------
        run = slice_job >= 0
        sjc = jnp.clip(slice_job, 0, J - 1)
        slots_of = slice_slots[cfg]  # (S,)
        slot_s = jnp.where(run, slots_of, 0)
        rem_s = remaining[sjc]
        rate_s = rates[sjc, slot_s]
        fin = jnp.where(run & (rate_s > 0),
                        rem_s / jnp.maximum(rate_s, 1e-12), jnp.inf)
        run_time = jnp.where(run, jnp.minimum(fin, dt), 0.0)
        done = run & (fin <= dt + _T_EPS)
        comp_t = t + fin
        new_rem_s = jnp.where(done, 0.0,
                              jnp.maximum(rem_s - rate_s * dt, 0.0))
        # (J,)-array writes are deferred and merged with the handoff's into
        # one scatter per array — scatters carry a large fixed cost on CPU
        busy_minutes = jnp.sum(slot_s * run_time)

        # tardiness: each in-system job accrues overlap of its busy/waiting
        # span with [deadline, inf); jobs completing mid-step get the
        # overshoot past their exact completion refunded (S-space)
        tard = tard + jnp.sum(jnp.where(
            insys, jnp.maximum(t + dt - jnp.maximum(deadline, t), 0.0), 0.0
        ))
        base_s = jnp.maximum(deadline[sjc], t)
        over = jnp.where(done,
                         jnp.maximum(t + dt - base_s, 0.0)
                         - jnp.maximum(comp_t - base_s, 0.0), 0.0)
        tard = tard - jnp.sum(over)
        held = slice_job  # lane->job ids before done lanes are cleared
        slice_job = jnp.where(done, -1, slice_job)

        # -- 4b. same-step handoff of freed capacity --------------------
        # the oracle reassigns at the completion event; without this pass a
        # deep queue on few slices loses up to dt per handoff and the error
        # compounds down the queue.  One round per step (no cascading):
        # the r-th freed slice (fastest-first) runs the r-th waiting job
        # (EDF-first: candidates num_slices.. of the buffer built above).
        leftover = jnp.where(done & (~in_flight), dt - run_time, 0.0)
        nsl = num_slices[cfg]
        fr = jnp.where(ranked >= 0,
                       leftover[jnp.clip(ranked, 0, S - 1)], 0.0)
        has = fr > _T_EPS
        hrk = jnp.cumsum(has.astype(i32))
        hpos = jnp.where(has, hrk - 1, S)
        fslice = jnp.full((S,), -1, i32).at[hpos].set(
            jnp.where(has, ranked, -1), mode="drop")
        fgive = jnp.zeros((S,), jnp.float32).at[hpos].set(
            jnp.where(has, fr, 0.0), mode="drop")
        wjob = cand[jnp.clip(nsl + jnp.arange(S, dtype=i32), 0, 2 * S - 1)]
        wok = (fslice >= 0) & (wjob < J)
        wjc = jnp.clip(wjob, 0, J - 1)
        w_rem = remaining[wjc]  # they were waiting: untouched by phase 4
        slot_w = slots_of[jnp.clip(fslice, 0, S - 1)]
        rate_w = rates[wjc, jnp.where(wok, slot_w, 0)]
        fin_w = jnp.where(wok & (rate_w > 0),
                          w_rem / jnp.maximum(rate_w, 1e-12), jnp.inf)
        h_done = wok & (fin_w <= fgive + _T_EPS)
        tc = (t + dt - fgive) + fin_w
        new_wrem = jnp.where(h_done, 0.0,
                             jnp.maximum(w_rem - rate_w * fgive, 0.0))
        # merged write-back: running jobs (phase 4) and handoff jobs touch
        # disjoint index sets, so one (2S,) scatter per array suffices
        rem_idx = jnp.concatenate([jnp.where(run, held, J),
                                   jnp.where(wok, wjob, J)])
        remaining = remaining.at[rem_idx].set(
            jnp.concatenate([new_rem_s, new_wrem]), mode="drop")
        comp_idx = jnp.concatenate([jnp.where(done, held, J),
                                    jnp.where(h_done, wjob, J)])
        completion = completion.at[comp_idx].set(
            jnp.concatenate([comp_t, tc]), mode="drop")
        busy_minutes = busy_minutes + jnp.sum(jnp.where(
            wok, slot_w * jnp.minimum(fin_w, fgive), 0.0))
        # it accrued tardiness as waiting-to-step-end; completing at tc
        # refunds the overshoot
        base_w = jnp.maximum(deadline[wjc], t)
        refund = (jnp.maximum(t + dt - base_w, 0.0)
                  - jnp.maximum(tc - base_w, 0.0))
        tard = tard - jnp.sum(jnp.where(h_done, refund, 0.0))

        # -- rollout end detection --------------------------------------
        all_done = ~jnp.any(valid & (remaining > _W_EPS))
        finishes = all_done & (~jnp.isfinite(stop_time))
        e = jnp.maximum(jnp.maximum(
            jnp.max(jnp.where(done, comp_t, -jnp.inf)),
            jnp.max(jnp.where(h_done, tc, -jnp.inf))), t)
        if kind == "daynight":
            # the oracle still fires the one pending boundary timer after
            # the last completion (idle until the boundary, then switches)
            base = jnp.floor(e / _DAY) * _DAY
            cands = jnp.stack([
                base + day_start, base + day_end,
                base + _DAY + day_start, base + _DAY + day_end,
            ])
            end_stop = jnp.min(jnp.where(cands > e + _T_EPS, cands, jnp.inf))
        else:
            end_stop = e
        stop_time = jnp.where(finishes, end_stop, stop_time)

        # -- 5. energy / busy / histogram over the accounted span -------
        span = jnp.clip(jnp.minimum(t + dt, stop_time) - t, 0.0, dt)
        busy_min = busy_min + busy_minutes
        avg_busy = jnp.where(
            span > _T_EPS, busy_minutes / jnp.maximum(span, _T_EPS), 0.0
        )
        lo = jnp.clip(jnp.floor(avg_busy).astype(i32), 0, max_slots)
        hi = jnp.clip(lo + 1, 0, max_slots)
        frac = jnp.clip(avg_busy - lo.astype(jnp.float32), 0.0, 1.0)
        watts_now = watts[lo] * (1.0 - frac) + watts[hi] * frac
        energy = energy + watts_now * span / 60.0
        level = jnp.clip(jnp.sum(slot_s), 0, max_slots)
        hist = hist.at[level].add(span)

        stall_left = jnp.maximum(stall_left - dt, 0.0)
        return RolloutState(
            remaining, completion, slice_job, cfg, pending, stall_left,
            stop_time, energy, tard, busy_min, pre, rep, hist,
        )

    return step_one


@functools.lru_cache(maxsize=None)
def _chunk_fn(kind: str, dt: float, n_steps: int, penalty: float,
              day_start: float, day_end: float):
    """Build (and cache) the jitted scan over ``n_steps`` for one policy kind."""
    import jax
    import jax.numpy as jnp
    from jax import lax

    step_one = make_step_fn(kind, dt, penalty, day_start, day_end)

    @jax.jit
    def run_chunk(state, arrival, deadline, rates, valid, dorder,
                  primary, secondary, t0,
                  slice_slots, slice_rank, num_slices, o2n, watts):
        step_b = jax.vmap(
            step_one,
            in_axes=(0, None, 0, 0, 0, 0, 0, 0, 0,
                     None, None, None, None, None),
        )

        def body(carry, i):
            t = t0 + i.astype(jnp.float32) * jnp.float32(dt)
            return (
                step_b(carry, t, arrival, deadline, rates, valid, dorder,
                       primary, secondary,
                       slice_slots, slice_rank, num_slices, o2n, watts),
                None,
            )

        state, _ = lax.scan(body, state, jnp.arange(n_steps, dtype=jnp.int32))
        return state

    return run_chunk


def run_steps(
    state: RolloutState,
    jobs: BatchedJobs,
    policy: BatchedPolicy,
    consts: Dict[str, Any],
    *,
    t0_min: float,
    n_steps: int,
    dt_min: float = DEFAULT_DT_MIN,
    penalty_min: Optional[float] = None,
) -> RolloutState:
    """Advance every rollout ``n_steps`` grid steps from ``t0_min``.

    The building block both :func:`simulate_batch` and the RL env share;
    the compiled program is cached per (policy kind, dt, n_steps) so
    repeated calls with the same shapes are compile-free.
    """
    import jax.numpy as jnp

    if penalty_min is None:
        from repro.core.simulator import REPARTITION_PENALTY_MIN

        penalty_min = REPARTITION_PENALTY_MIN
    if jobs.padded_jobs % _BLOCK != 0:
        raise ValueError(
            f"padded job axis {jobs.padded_jobs} must be a multiple of "
            f"{_BLOCK} (use BatchedJobs.from_job_lists, which pads to it)"
        )
    fn = _chunk_fn(
        policy.kind, float(dt_min), int(n_steps), float(penalty_min),
        float(policy.day_start), float(policy.day_end),
    )
    return fn(
        state,
        jnp.asarray(jobs.arrival), jnp.asarray(jobs.deadline),
        jnp.asarray(jobs.rate_by_slots), jnp.asarray(jobs.valid),
        jnp.asarray(jobs.edf_order),
        jnp.asarray(policy.primary), jnp.asarray(policy.secondary),
        jnp.float32(t0_min),
        consts["slice_slots"], consts["slice_rank"], consts["num_slices"],
        consts["old_to_new"], consts["watts"],
    )


def result_of(
    state: RolloutState, jobs: BatchedJobs, tables: DeviceTables
) -> BatchedResult:
    """Materialize a finished carry into a host-side :class:`BatchedResult`."""
    stop = np.asarray(state.stop_time, dtype=np.float64)
    return BatchedResult(
        energy_wh=np.asarray(state.energy_wh, dtype=np.float64),
        tardiness_integral=np.asarray(state.tardiness_integral, np.float64),
        busy_slot_minutes=np.asarray(state.busy_slot_minutes, np.float64),
        preemptions=np.asarray(state.preemptions, dtype=np.int64),
        repartitions=np.asarray(state.repartitions, dtype=np.int64),
        completion=np.asarray(state.completion, dtype=np.float64),
        deadline=np.asarray(jobs.deadline, dtype=np.float64),
        valid=np.asarray(jobs.valid),
        num_jobs=np.asarray(jobs.num_jobs, dtype=np.int64),
        makespan_min=stop,
        util_histogram=np.asarray(state.util_hist, dtype=np.float64),
    )


def _horizon_bound(jobs: BatchedJobs) -> float:
    """A conservative makespan bound: serial 1g execution + two day cycles.

    Every job depletes at rate >= 1 on a 1-slot slice and EDF-FS always runs
    the queue head, so total work past the last arrival bounds the busy tail;
    the slack covers the DayNight post-drain boundary wait.
    """
    arr = np.where(jobs.valid, jobs.arrival, 0.0)
    work = np.where(jobs.valid, jobs.work, 0.0)
    per = arr.max(axis=1, initial=0.0) + work.sum(axis=1)
    return float(per.max(initial=0.0) + 2 * _DAY + 10.0)


def simulate_batch(
    jobs: BatchedJobs,
    policy: BatchedPolicy,
    *,
    tables: Optional[DeviceTables] = None,
    repartition_mode: str = "partial",
    dt_min: float = DEFAULT_DT_MIN,
    chunk_steps: int = DEFAULT_CHUNK_STEPS,
    max_minutes: Optional[float] = None,
) -> BatchedResult:
    """Run every rollout to completion; the batched analogue of ``sim.run``.

    ``dt_min`` must divide 60 (so DayNight boundaries are grid points);
    ``max_minutes`` overrides the livelock guard (default: a conservative
    serial-execution bound).  Returns per-rollout aggregates; see
    docs/BATCHED_SIM.md §4 for how far they may drift from the oracle.
    """
    if tables is None:
        tables = build_tables()
    if abs(round(60.0 / dt_min) * dt_min - 60.0) > 1e-9:
        raise ValueError(f"dt_min={dt_min} must divide 60 minutes")
    if policy.batch != jobs.batch:
        raise ValueError(
            f"policy compiled for batch {policy.batch}, jobs batch {jobs.batch}"
        )
    if jobs.rate_by_slots.shape[2] != tables.max_slots + 1:
        raise ValueError("jobs rate table was built for a different device")
    consts = device_constants(tables, repartition_mode)
    state = init_state(jobs, policy.initial)
    bound = _horizon_bound(jobs) if max_minutes is None else float(max_minutes)

    steps_done = 0
    while True:
        state = run_steps(
            state, jobs, policy, consts,
            t0_min=steps_done * dt_min, n_steps=chunk_steps, dt_min=dt_min,
            penalty_min=tables.penalty_min,
        )
        steps_done += chunk_steps
        t_now = steps_done * dt_min
        stop = np.asarray(state.stop_time)
        if np.all(stop < t_now):
            break
        if t_now > bound:
            raise RuntimeError(
                f"batched rollout still live at t={t_now:.0f} min "
                f"(bound {bound:.0f}); unfinished rollouts: "
                f"{int(np.sum(~(stop < t_now)))}"
            )
    return result_of(state, jobs, tables)
