"""Vectorized repartitioning environment over the batched backend.

:class:`BatchedRepartitionEnv` is the fleet-of-episodes counterpart of
:class:`repro.core.rl.env.RepartitionEnv`: one ``reset`` builds ``B``
independent episodes (one per seed) and every ``step`` applies a *vector*
of configuration actions, advancing all episodes one decision interval in
a single jitted scan.

Contract differences from the oracle env (documented, docs/BATCHED_SIM.md §5):

* decisions happen on a fixed cadence (``decision_interval_min``), not at
  every arrival/completion event — the agent re-plans on a clock, and the
  chosen configuration is held in between;
* observations use the same §IV-D-1 feature layout (2+2m binned features,
  identical bin edges and sentinels), computed host-side from the carry;
* rewards are the same ET-scalarized interval rewards with the §IV-D-3
  switch penalty; per-rollout, as a ``(B,)`` vector.

Only EDF-FS is available (the one scheduler the batched backend
implements); training scripts that need EDF-SS semantics keep using the
oracle env.
"""

from __future__ import annotations

from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.batched.backend import (
    DEFAULT_DT_MIN,
    device_constants,
    init_state,
    result_of,
    run_steps,
)
from repro.core.batched.policies import held_policy
from repro.core.batched.state import BatchedJobs
from repro.core.batched.tables import DeviceTables, build_tables
from repro.core.jobs import ALL_SLICE_SIZES
from repro.core.metrics import SimResult
# same feature discretization as the oracle env (§IV-D-1): the bin tables
# are the contract between the two envs, so import rather than duplicate
from repro.core.rl.env import _BIN_EDGES, _NUM_BINS, _TIME_BINS, M_JOBS, RewardWeights

__all__ = ["BatchedRepartitionEnv"]

_EPS = 1e-6


class BatchedRepartitionEnv:
    """Gym-style vectorized env: ``(B,)`` actions in, ``(B,)`` rewards out.

    Actions are config indices ``0..C-1`` mapping to configuration ids
    ``1..C`` (the paper's Fig. 1 table by default); choosing the current
    configuration is a no-op.  ``step`` returns
    ``(obs (B, 2+2m), reward (B,), terminated (B,), truncated (B,), info)``.
    """

    def __init__(
        self,
        scheduler_name: str = "EDF-FS",
        scenario: Optional[str] = None,
        scenario_kwargs: Optional[Dict[str, Any]] = None,
        spec=None,
        rewards: RewardWeights = RewardWeights(),
        initial_config: int = 2,
        mig_enabled: bool = True,
        repartition_mode: str = "partial",
        decision_interval_min: float = 15.0,
        dt_min: float = DEFAULT_DT_MIN,
        truncate_after_min: Optional[float] = None,
        max_decisions: Optional[int] = None,
        m: int = M_JOBS,
        tables: Optional[DeviceTables] = None,
    ) -> None:
        if scheduler_name != "EDF-FS":
            raise ValueError(
                f"batched env supports only EDF-FS (got {scheduler_name!r}); "
                "use repro.core.rl.env.RepartitionEnv for other schedulers"
            )
        steps = decision_interval_min / dt_min
        if abs(round(steps) - steps) > 1e-9 or round(steps) < 1:
            raise ValueError(
                f"decision_interval_min={decision_interval_min} must be a "
                f"positive multiple of dt_min={dt_min}"
            )
        from repro.core.workload import WorkloadSpec

        self.spec = spec or WorkloadSpec()
        self.scenario = scenario
        self.scenario_kwargs = dict(scenario_kwargs or {})
        self.rewards = rewards
        self.initial_config = initial_config
        self.mig_enabled = mig_enabled
        self.repartition_mode = repartition_mode
        self.dt_min = float(dt_min)
        self.steps_per_decision = int(round(steps))
        self.truncate_after_min = truncate_after_min
        self.max_decisions = max_decisions
        self.m = m
        self.tables = tables if tables is not None else build_tables()
        self._consts = device_constants(self.tables, repartition_mode)
        self._state = None
        self._jobs: Optional[BatchedJobs] = None
        self._inv_mean_dur: Optional[np.ndarray] = None
        self._t = 0.0
        self._decisions = 0
        self._halted: Optional[np.ndarray] = None

    # ------------------------------------------------------------------
    def reset(
        self,
        seeds: Sequence[int] = (0,),
        job_lists: Optional[Sequence[Sequence[Any]]] = None,
    ) -> np.ndarray:
        """Start ``B`` fresh episodes; returns the ``(B, 2+2m)`` observation.

        ``seeds`` draws one job stream per rollout from the scenario (or
        :class:`WorkloadSpec`); ``job_lists`` overrides them directly.
        """
        from repro.core.scenarios import generate_scenario
        from repro.core.workload import generate_jobs

        if job_lists is None:
            if self.scenario is not None:
                job_lists = [
                    generate_scenario(self.scenario, seed=s, **self.scenario_kwargs)
                    for s in seeds
                ]
            else:
                job_lists = [generate_jobs(self.spec, seed=s) for s in seeds]
        self._jobs = BatchedJobs.from_job_lists(
            job_lists, max_slots=self.tables.max_slots,
            mig_enabled=self.mig_enabled,
        )
        B, J = self._jobs.arrival.shape
        # mean-duration feature: duration averaged over the canonical slice
        # sizes at mig=True (Job.mean_duration_all_sizes), linear in the
        # remaining work, so one per-job coefficient suffices
        inv = np.zeros((B, J), dtype=np.float64)
        for b, jobs in enumerate(job_lists):
            for j, job in enumerate(jobs):
                inv[b, j] = sum(
                    1.0 / job.rate_on(float(k), True) for k in ALL_SLICE_SIZES
                ) / len(ALL_SLICE_SIZES)
        self._inv_mean_dur = inv
        init_idx = np.full((B,), self.tables.index_of(self.initial_config),
                           dtype=np.int32)
        self._state = init_state(self._jobs, init_idx)
        self._t = 0.0
        self._decisions = 0
        self._halted = np.zeros((B,), dtype=bool)
        return self._obs()

    def step(
        self, actions: Sequence[int]
    ) -> Tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray, Dict[str, Any]]:
        """Apply per-rollout actions and advance one decision interval."""
        if self._state is None or self._jobs is None:
            raise RuntimeError("call reset() first")
        if self.done:
            raise RuntimeError("all episodes over; call reset()")
        acts = np.asarray(actions, dtype=np.int64)
        B = self._jobs.batch
        if acts.shape != (B,):
            raise ValueError(f"actions shape {acts.shape} != ({B},)")
        config_ids = np.asarray(self.tables.config_ids)
        if acts.min() < 0 or acts.max() >= len(config_ids):
            raise ValueError(
                f"actions must be in [0, {len(config_ids) - 1}]"
            )
        targets = acts.astype(np.int32)  # dense index == id-1 for Fig. 1
        cur = np.asarray(self._state.cfg)
        switched = targets != cur
        # §IV-D-3 switch penalty, priced on the jobs currently in system
        remaining = np.asarray(self._state.remaining)
        arrived = np.asarray(self._jobs.arrival) <= self._t + _EPS
        in_sys = (arrived & (remaining > _EPS) & self._jobs.valid).sum(axis=1)
        w = self.rewards
        pen_y = w.switch_penalty_min * np.maximum(in_sys, 1) / w.tardiness_norm
        penalty = np.where(switched, (pen_y / (w.a + 1.0)) / w.scale, 0.0)

        e0 = np.asarray(self._state.energy_wh, dtype=np.float64)
        td0 = np.asarray(self._state.tardiness_integral, dtype=np.float64)
        self._state = run_steps(
            self._state, self._jobs, held_policy(targets, cur), self._consts,
            t0_min=self._t, n_steps=self.steps_per_decision,
            dt_min=self.dt_min, penalty_min=self.tables.penalty_min,
        )
        self._t += self.steps_per_decision * self.dt_min
        self._decisions += 1

        d_e = np.asarray(self._state.energy_wh, dtype=np.float64) - e0
        d_t = np.asarray(self._state.tardiness_integral, dtype=np.float64) - td0
        reward = w.interval_reward(d_e, d_t) - penalty

        stop = np.asarray(self._state.stop_time)
        terminated = stop <= self._t + _EPS
        truncated = np.zeros_like(terminated)
        if self.truncate_after_min is not None and self._t >= self.truncate_after_min:
            truncated = ~terminated
        if self.max_decisions is not None and self._decisions >= self.max_decisions:
            truncated = ~terminated
        self._halted = terminated | truncated

        info = {
            "t": self._t,
            "switched": switched,
            "config_id": config_ids[np.asarray(self._state.cfg)],
            "decisions": self._decisions,
            "queue_depth": np.maximum(
                in_sys - (np.asarray(self._state.slice_job) >= 0).sum(axis=1),
                0,
            ),
        }
        return self._obs(), reward, terminated, truncated, info

    @property
    def done(self) -> bool:
        """True once every rollout has terminated or been truncated."""
        return self._halted is not None and bool(self._halted.all())

    def results(self) -> List[SimResult]:
        """Per-rollout :class:`SimResult` (meaningful for terminated rollouts)."""
        if self._state is None or self._jobs is None:
            raise RuntimeError("no episode has run")
        return result_of(self._state, self._jobs, self.tables).to_sim_results()

    # ------------------------------------------------------------------
    def _obs(self) -> np.ndarray:
        """§IV-D-1 features per rollout: config, time, m×(slack, duration)."""
        jobs = self._jobs
        state = self._state
        assert jobs is not None and state is not None
        t = self._t
        B, J = jobs.arrival.shape
        remaining = np.asarray(state.remaining, dtype=np.float64)
        slice_job = np.asarray(state.slice_job)
        cfg_ids = np.asarray(self.tables.config_ids)[np.asarray(state.cfg)]
        arrival = np.asarray(jobs.arrival, dtype=np.float64)
        deadline = np.asarray(jobs.deadline, dtype=np.float64)

        running = np.zeros((B, J), dtype=bool)
        rows, lanes = np.nonzero(slice_job >= 0)
        running[rows, slice_job[rows, lanes]] = True

        obs = np.zeros((B, 2 + 2 * self.m), dtype=np.float32)
        obs[:, 0] = (cfg_ids - 1) / 11.0
        tod = (t / 60.0) % 24.0
        obs[:, 1] = int(tod * 2) % _TIME_BINS / (_TIME_BINS - 1)
        queued = (
            (arrival <= t + _EPS) & (remaining > _EPS)
            & (~running) & jobs.valid
        )
        for b in range(B):
            idx = np.flatnonzero(queued[b])
            # EDF order; stable sort keeps (arrival, job_id) tie order
            idx = idx[np.argsort(deadline[b, idx], kind="stable")]
            for i in range(self.m):
                if i < len(idx):
                    j = idx[i]
                    slack = max(deadline[b, j] - t, 0.0)
                    mean_dur = remaining[b, j] * self._inv_mean_dur[b, j]
                    obs[b, 2 + 2 * i] = (
                        np.searchsorted(_BIN_EDGES, slack, side="right")
                        / (_NUM_BINS - 1)
                    )
                    obs[b, 3 + 2 * i] = (
                        np.searchsorted(_BIN_EDGES, mean_dur, side="right")
                        / (_NUM_BINS - 1)
                    )
                else:
                    obs[b, 2 + 2 * i] = 1.0  # "no job" sentinel: max slack
                    obs[b, 3 + 2 * i] = 0.0
        return obs
