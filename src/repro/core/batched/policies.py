"""Batchable repartitioning policies: compiled specs, not Python callbacks.

The oracle consults a :class:`repro.core.simulator.RepartitionPolicy` object
at every event; inside a ``lax.scan`` there is no room for a Python callback
per step, so the batched backend supports exactly the policies whose target
configuration is a closed-form function of time:

* ``static`` / ``nomig`` — one fixed configuration;
* ``daynight`` — the twice-daily §V-A benchmark (day config during
  [day_start, day_end) minutes-of-day, night config otherwise).

Stateful policies (``heuristic``, ``dqn``, ``forecast``) observe simulator
state and must run on the oracle — or, for RL, through
:class:`repro.core.batched.env.BatchedRepartitionEnv`, which re-plans at a
fixed decision cadence and holds the chosen target in between (the
``static`` fast path with a fresh target array per interval).

:func:`compile_policy` inspects a *fresh oracle policy instance* built by
the sweep registry, so batched cells honour exactly the defaults oracle
cells get and unsupported policies fail loudly.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import numpy as np

from repro.core.batched.tables import DeviceTables
from repro.core.simulator import DayNightPolicy, RepartitionPolicy, StaticPolicy

__all__ = ["BatchedPolicy", "UnsupportedPolicyError", "compile_policy", "held_policy"]


class UnsupportedPolicyError(ValueError):
    """Raised when a policy/scheduler cannot run on the batched backend."""


@dataclasses.dataclass(frozen=True)
class BatchedPolicy:
    """A policy compiled to per-rollout config-index arrays.

    ``kind`` is ``"static"`` (target = ``primary``) or ``"daynight"``
    (target = ``primary`` during [``day_start``, ``day_end``) minutes of
    day, else ``secondary``).  All config values are *dense indices* into
    :class:`DeviceTables`, not 1-based config ids.
    """

    kind: str  # "static" | "daynight"
    initial: np.ndarray  # (B,) int32 config indices at t=0
    primary: np.ndarray  # (B,) int32 (static target / day config)
    secondary: np.ndarray  # (B,) int32 (daynight night config; unused static)
    day_start: float = 5 * 60.0
    day_end: float = 17 * 60.0

    @property
    def batch(self) -> int:
        """``B`` — rollout count this policy is compiled for."""
        return int(self.initial.shape[0])


def _bcast(values: Sequence[int], batch: int) -> np.ndarray:
    arr = np.asarray(values, dtype=np.int32)
    if arr.ndim == 0:
        arr = arr[None]
    if arr.shape[0] == 1 and batch > 1:
        arr = np.repeat(arr, batch)
    if arr.shape[0] != batch:
        raise ValueError(f"policy spec covers {arr.shape[0]} rollouts, batch is {batch}")
    return arr


def compile_policy(
    policy: RepartitionPolicy,
    tables: DeviceTables,
    batch: int,
    initial_config: Optional[int] = None,
) -> BatchedPolicy:
    """Compile one oracle policy instance for a ``batch``-wide rollout.

    ``initial_config`` overrides the policy's own ``initial_config`` (the
    same override :class:`SimulationEngine` accepts).  Raises
    :class:`UnsupportedPolicyError` for policies that need simulator state.
    """
    init_id = policy.initial_config if initial_config is None else initial_config
    init = _bcast([tables.index_of(int(init_id))], batch)
    if isinstance(policy, DayNightPolicy):
        return BatchedPolicy(
            kind="daynight",
            initial=init,
            primary=_bcast([tables.index_of(policy.day_config)], batch),
            secondary=_bcast([tables.index_of(policy.night_config)], batch),
            day_start=float(policy.day_start),
            day_end=float(policy.day_end),
        )
    # NoMIGPolicy subclasses StaticPolicy, so this covers static + nomig.
    if isinstance(policy, StaticPolicy):
        return BatchedPolicy(
            kind="static", initial=init, primary=init, secondary=init
        )
    raise UnsupportedPolicyError(
        f"policy {type(policy).__name__} needs per-event simulator state; "
        "the batched backend supports static/nomig/daynight (and the RL env's "
        "held-target stepping) — run this cell on the oracle backend"
    )


def held_policy(targets: np.ndarray, current: np.ndarray) -> BatchedPolicy:
    """A per-rollout held-target policy (the RL env decision interval).

    ``targets`` are dense config indices to switch to (and hold); ``current``
    seeds ``initial`` so no switch is charged when a rollout keeps its
    configuration.
    """
    targets = np.asarray(targets, dtype=np.int32)
    current = np.asarray(current, dtype=np.int32)
    if targets.shape != current.shape:
        raise ValueError("targets/current shape mismatch")
    return BatchedPolicy(
        kind="static", initial=current, primary=targets, secondary=targets
    )
