"""Power models.

The paper measures (Fig. 3, A100 at the default 250 W cap) that

* idle power is substantial (~65 W),
* marginal power of the first few busy slots is steep,
* after ~4 of 7 slots are busy additional slots cost almost nothing,
* the difference between many small busy slices and one equal-sized large busy
  slice is <10 % (usually <5 %) and is ignored for modelling.

So power is a *concave, saturating* function of busy compute slots — NOT the
"speed^alpha" power law common in the literature (paper §IV intro).  We encode
Fig. 3 as a lookup on busy slots 0..7 with linear interpolation (fractional
busy slots arise only in the TPU-cluster adaptation).

Energy below is reported in watt-hours; the simulator's time unit is minutes.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence, Tuple

__all__ = ["PowerModel", "A100_250W", "A30_165W", "TPU_V5E_POD", "make_saturating_power"]


@dataclasses.dataclass(frozen=True)
class PowerModel:
    """Piecewise-linear power (watts) vs number of busy compute slots."""

    name: str
    watts_by_busy_slots: Tuple[float, ...]  # index 0 == idle
    total_slots: int

    def __post_init__(self) -> None:
        if len(self.watts_by_busy_slots) != self.total_slots + 1:
            raise ValueError("need total_slots+1 power entries (incl. idle)")
        w = self.watts_by_busy_slots
        if any(b > a + 1e-9 for a, b in zip(w[1:], w, strict=False)):
            raise ValueError("power must be nondecreasing in busy slots")

    def power_watts(self, busy_slots: float) -> float:
        """Power draw with ``busy_slots`` compute slots busy (interpolated)."""
        u = min(max(busy_slots, 0.0), float(self.total_slots))
        lo = int(u)
        hi = min(lo + 1, self.total_slots)
        frac = u - lo
        w = self.watts_by_busy_slots
        return w[lo] * (1.0 - frac) + w[hi] * frac

    def energy_wh(self, busy_slots: float, minutes: float) -> float:
        """Energy in watt-hours for an interval at constant utilization."""
        return self.power_watts(busy_slots) * minutes / 60.0

    @property
    def idle_watts(self) -> float:
        return self.watts_by_busy_slots[0]

    @property
    def peak_watts(self) -> float:
        return self.watts_by_busy_slots[-1]


# Fig. 3 (A100-40GB, 250 W cap): steep marginal power up to 4 busy slots, then
# nearly flat.  Exact tabular values are not published; these reproduce the
# described shape (see DESIGN.md §2 "assumption changes").
A100_250W = PowerModel(
    name="a100-40gb-250w",
    watts_by_busy_slots=(65.0, 135.0, 185.0, 222.0, 243.0, 248.0, 250.0, 250.0),
    total_slots=7,
)


def make_saturating_power(
    name: str,
    idle_watts: float,
    peak_watts: float,
    total_slots: int,
    knee_fraction: float = 4.0 / 7.0,
    sharpness: float = 2.2,
) -> PowerModel:
    """Build a Fig.-3-shaped saturating power curve for other hardware.

    ``P(u) = idle + (peak-idle) * (1 - exp(-s*u/k)) / (1 - exp(-s/k))`` with
    ``k = knee_fraction`` — rises steeply until the knee then flattens.
    """
    import math

    k = knee_fraction
    s = sharpness
    denom = 1.0 - math.exp(-s / k)
    watts = []
    for i in range(total_slots + 1):
        u = i / total_slots
        frac = (1.0 - math.exp(-s * u / k)) / denom
        watts.append(idle_watts + (peak_watts - idle_watts) * frac)
    # enforce monotone (numerical safety) and exact endpoints
    for i in range(1, len(watts)):
        watts[i] = max(watts[i], watts[i - 1])
    watts[-1] = max(watts[-1], peak_watts)
    return PowerModel(name=name, watts_by_busy_slots=tuple(watts), total_slots=total_slots)


# A30-class fleet profile (24GB, 165 W TDP, 4 MIG compute slots): Fig. 3 was
# only measured on the A100, so we reuse its saturating shape at A30 scale —
# idle ~30 W, steep marginal power to the knee, near-flat after.
A30_165W = make_saturating_power(
    name="a30-24gb-165w",
    idle_watts=30.0,
    peak_watts=165.0,
    total_slots=4,
)


# TPU v5e pod adaptation: 256 chips grouped into 7 "slots" of ~36 chips.
# Idle ~100 W/chip, busy ~300 W/chip => pod idle 25.6 kW, peak 76.8 kW.
# Same saturating shape as Fig. 3 (shared power delivery/cooling overheads
# dominate at low utilization).  Units remain watts.
TPU_V5E_POD = make_saturating_power(
    name="tpu-v5e-pod-256",
    idle_watts=256 * 100.0,
    peak_watts=256 * 300.0,
    total_slots=7,
)
