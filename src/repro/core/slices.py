"""MIG slice model and the 12 partition configurations of Fig. 1.

The paper partitions an A100-40GB into slices of compute size 1, 2, 3, 4 or 7
"slots" (SM fractions) with an associated memory size.  Only 12 configurations
(Fig. 1) are considered; configuration ids are 1-based to match the paper.

This module is hardware-agnostic: a :class:`SliceType` is (compute slots,
memory GB) and a :class:`Partition` is an ordered tuple of slice types.  The
TPU adaptation (``repro.cluster``) reuses the same partition table with chips
substituted for SM slots (see DESIGN.md §2).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Sequence, Tuple

__all__ = [
    "SliceType",
    "Partition",
    "MIG_CONFIGS",
    "A30_CONFIGS",
    "NUM_CONFIGS",
    "TOTAL_SLOTS",
    "ALL_SLICE_SIZES",
    "config",
    "config_ids",
    "validate_config_table",
]

TOTAL_SLOTS = 7
ALL_SLICE_SIZES = (1, 2, 3, 4, 7)


@dataclasses.dataclass(frozen=True)
class SliceType:
    """A MIG slice type, e.g. ``2g.10gb`` -> SliceType(2, 10)."""

    slots: int  # compute size in "g" units (1,2,3,4,7)
    memory_gb: int

    def __post_init__(self) -> None:
        if self.slots not in ALL_SLICE_SIZES:
            raise ValueError(f"invalid slice size {self.slots}g")

    @property
    def name(self) -> str:
        return f"{self.slots}g.{self.memory_gb}gb"

    def __str__(self) -> str:  # pragma: no cover - repr sugar
        return self.name


# Shorthand constructors for the A100-40GB slice types used in Fig. 1.
S1_5 = SliceType(1, 5)
S1_10 = SliceType(1, 10)
S2_10 = SliceType(2, 10)
S3_20 = SliceType(3, 20)
S4_20 = SliceType(4, 20)
S7_40 = SliceType(7, 40)


@dataclasses.dataclass(frozen=True)
class Partition:
    """An ordered MIG partition (one row of Fig. 1)."""

    config_id: int
    slices: Tuple[SliceType, ...]

    @property
    def num_slices(self) -> int:
        return len(self.slices)

    @property
    def total_slots(self) -> int:
        return sum(s.slots for s in self.slices)

    @property
    def total_memory_gb(self) -> int:
        return sum(s.memory_gb for s in self.slices)

    def slot_sizes(self) -> Tuple[int, ...]:
        return tuple(s.slots for s in self.slices)

    def fastest_slice_index(self) -> int:
        """Index of the largest-compute slice (ties -> first)."""
        return max(range(len(self.slices)), key=lambda i: self.slices[i].slots)

    def slowest_slice_index(self) -> int:
        return min(range(len(self.slices)), key=lambda i: self.slices[i].slots)

    def sorted_indices(self, descending: bool = False) -> List[int]:
        """Slice indices sorted by compute size ascending (or descending)."""
        return sorted(
            range(len(self.slices)),
            key=lambda i: self.slices[i].slots,
            reverse=descending,
        )

    def __str__(self) -> str:  # pragma: no cover - repr sugar
        body = " + ".join(s.name for s in self.slices)
        return f"cfg{self.config_id}[{body}]"


def _mk(config_id: int, *slices: SliceType) -> Partition:
    return Partition(config_id=config_id, slices=tuple(slices))


# Fig. 1 — the 12 configurations of an A100-40GB considered by the paper.
MIG_CONFIGS: Dict[int, Partition] = {
    1: _mk(1, S7_40),
    2: _mk(2, S4_20, S3_20),
    3: _mk(3, S4_20, S2_10, S1_10),
    4: _mk(4, S4_20, S1_5, S1_5, S1_10),
    5: _mk(5, S3_20, S3_20),  # note: 1-slot "hole" (6 of 7 slots used)
    6: _mk(6, S2_10, S2_10, S3_20),
    7: _mk(7, S2_10, S1_5, S1_5, S3_20),
    8: _mk(8, S1_5, S1_5, S1_5, S1_5, S3_20),
    9: _mk(9, S2_10, S2_10, S2_10, S1_10),
    10: _mk(10, S2_10, S2_10, S1_5, S1_5, S1_10),
    11: _mk(11, S2_10, S1_5, S1_5, S1_5, S1_5, S1_10),
    12: _mk(12, S1_5, S1_5, S1_5, S1_5, S1_5, S1_5, S1_10),
}

NUM_CONFIGS = len(MIG_CONFIGS)


def config(config_id: int) -> Partition:
    """Return the partition for a 1-based Fig. 1 configuration id."""
    try:
        return MIG_CONFIGS[config_id]
    except KeyError as e:  # pragma: no cover - defensive
        raise KeyError(
            f"unknown MIG config {config_id}; valid ids {sorted(MIG_CONFIGS)}"
        ) from e


def config_ids() -> Sequence[int]:
    return tuple(sorted(MIG_CONFIGS))


# ----------------------------------------------------------------------
# A30-class device (24 GB, 4 compute slots): the second fleet profile.
# NVIDIA's A30 MIG geometry: 1g.6gb, 2g.12gb, 4g.24gb; four valid layouts.

A30_S1_6 = SliceType(1, 6)
A30_S2_12 = SliceType(2, 12)
A30_S4_24 = SliceType(4, 24)

A30_CONFIGS: Dict[int, Partition] = {
    1: _mk(1, A30_S4_24),
    2: _mk(2, A30_S2_12, A30_S2_12),
    3: _mk(3, A30_S2_12, A30_S1_6, A30_S1_6),
    4: _mk(4, A30_S1_6, A30_S1_6, A30_S1_6, A30_S1_6),
}


def validate_config_table(
    configs: Dict[int, Partition],
    max_slots: int,
    max_memory_gb: int,
    max_1g10_slices: int | None = None,
) -> None:
    """Sanity-check a device's partition table (invoked at import, cheap)."""
    for cid, part in configs.items():
        if part.config_id != cid:
            raise AssertionError(f"config id mismatch for {cid}")
        if part.total_slots > max_slots:
            raise AssertionError(f"config {cid} exceeds {max_slots} slots")
        if part.total_memory_gb > max_memory_gb:
            raise AssertionError(f"config {cid} exceeds {max_memory_gb}GB")
        if max_1g10_slices is not None:
            n_1g10 = sum(1 for s in part.slices if s == S1_10)
            if n_1g10 > max_1g10_slices:
                raise AssertionError(f"config {cid} has {n_1g10} 1g.10gb slices")


# A100 Fig. 1 table: at most one 1g.10gb slice per configuration (paper §III-A)
validate_config_table(MIG_CONFIGS, TOTAL_SLOTS, 40, max_1g10_slices=1)
validate_config_table(A30_CONFIGS, 4, 24)
