"""MIG slice model, slot placement, and the 12 configurations of Fig. 1.

The paper partitions an A100-40GB into slices of compute size 1, 2, 3, 4 or 7
"slots" (SM fractions) with an associated memory size.  Only 12 configurations
(Fig. 1) are considered; configuration ids are 1-based to match the paper.

Partitions are *slot-placed*: every slice occupies a concrete start offset on
the device's slot grid, subject to NVIDIA's placement alignment (a 2g slice
starts on even offsets, 3g/4g on multiples of four, 1g anywhere).  Placement
is what makes repartitioning *partial*: two configurations that place an
identical slice instance at the same offset share that GPU instance, and a
reconfiguration between them destroys/creates only the non-shared instances
(:func:`transition`) — jobs on shared instances keep running (DESIGN.md §7).

This module is hardware-agnostic: a :class:`SliceType` is (compute slots,
memory GB) and a :class:`Partition` is an ordered tuple of slice types with
their start offsets.  The TPU adaptation (``repro.cluster``) reuses the same
partition table with chips substituted for SM slots (see DESIGN.md §2).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

__all__ = [
    "SliceType",
    "Partition",
    "TransitionPlan",
    "MIG_CONFIGS",
    "A30_CONFIGS",
    "NUM_CONFIGS",
    "TOTAL_SLOTS",
    "ALL_SLICE_SIZES",
    "config",
    "config_ids",
    "placement_alignment",
    "auto_starts",
    "transition",
    "validate_config_table",
    "FreeSlotGeometry",
    "free_slot_geometry",
    "table_slice_sizes",
    "fleet_fragmentation",
]

TOTAL_SLOTS = 7
ALL_SLICE_SIZES = (1, 2, 3, 4, 7)


def placement_alignment(slots: int) -> int:
    """Start-offset alignment of a slice of ``slots`` compute units.

    Encodes NVIDIA's MIG placement grid: 1g slices may start anywhere, 2g
    slices on even offsets, 3g/4g (and the full-device 7g) on multiples of
    four.  On the A100's 7-slot grid this yields exactly the documented
    placements (1g: 0-6, 2g: {0,2,4}, 3g: {0,4}, 4g: {0}, 7g: {0}); the
    same rule reproduces the A30's 4-slot grid (2g: {0,2}, 4g: {0}).
    """
    if slots == 1:
        return 1
    if slots == 2:
        return 2
    return 4


def auto_starts(slot_sizes: Sequence[int]) -> Tuple[int, ...]:
    """Left-packed placement of ordered slices on the slot grid.

    Walks the slices in order, placing each at the lowest aligned offset at
    or after the previous slice's end.  This reproduces the canonical NVIDIA
    layout for every Fig. 1 configuration (including config 5's 1-slot hole:
    the second 3g slice skips offset 3 to its alignment boundary at 4).
    """
    starts: List[int] = []
    cursor = 0
    for slots in slot_sizes:
        a = placement_alignment(slots)
        start = ((cursor + a - 1) // a) * a
        starts.append(start)
        cursor = start + slots
    return tuple(starts)


@dataclasses.dataclass(frozen=True)
class SliceType:
    """A MIG slice type, e.g. ``2g.10gb`` -> SliceType(2, 10)."""

    slots: int  # compute size in "g" units (1,2,3,4,7)
    memory_gb: int

    def __post_init__(self) -> None:
        if self.slots not in ALL_SLICE_SIZES:
            raise ValueError(f"invalid slice size {self.slots}g")

    @property
    def name(self) -> str:
        return f"{self.slots}g.{self.memory_gb}gb"

    def __str__(self) -> str:  # pragma: no cover - repr sugar
        return self.name


# Shorthand constructors for the A100-40GB slice types used in Fig. 1.
S1_5 = SliceType(1, 5)
S1_10 = SliceType(1, 10)
S2_10 = SliceType(2, 10)
S3_20 = SliceType(3, 20)
S4_20 = SliceType(4, 20)
S7_40 = SliceType(7, 40)


@dataclasses.dataclass(frozen=True)
class Partition:
    """An ordered, slot-placed MIG partition (one row of Fig. 1).

    ``starts`` holds each slice's start offset on the device's slot grid;
    when omitted it is derived by :func:`auto_starts` (left-packed at NVIDIA
    placement alignment), which reproduces the canonical layout of every
    Fig. 1 configuration.
    """

    config_id: int
    slices: Tuple[SliceType, ...]
    starts: Optional[Tuple[int, ...]] = None

    def __post_init__(self) -> None:
        if self.starts is None:
            object.__setattr__(
                self, "starts", auto_starts(tuple(s.slots for s in self.slices))
            )
        elif len(self.starts) != len(self.slices):
            raise ValueError(
                f"config {self.config_id}: {len(self.starts)} starts for "
                f"{len(self.slices)} slices"
            )

    @property
    def num_slices(self) -> int:
        return len(self.slices)

    @property
    def total_slots(self) -> int:
        return sum(s.slots for s in self.slices)

    @property
    def total_memory_gb(self) -> int:
        return sum(s.memory_gb for s in self.slices)

    def slot_sizes(self) -> Tuple[int, ...]:
        return tuple(s.slots for s in self.slices)

    def fastest_slice_index(self) -> int:
        """Index of the largest-compute slice (ties -> first)."""
        return max(range(len(self.slices)), key=lambda i: self.slices[i].slots)

    def slowest_slice_index(self) -> int:
        return min(range(len(self.slices)), key=lambda i: self.slices[i].slots)

    def sorted_indices(self, descending: bool = False) -> List[int]:
        """Slice indices sorted by compute size ascending (or descending)."""
        return sorted(
            range(len(self.slices)),
            key=lambda i: self.slices[i].slots,
            reverse=descending,
        )

    def slice_instances(self) -> Tuple[Tuple[int, int, int], ...]:
        """Per-slice placement identity: ``(start, slots, memory_gb)``.

        Two configurations share a physical GPU instance exactly when both
        contain the same identity triple — the survival criterion of
        :func:`transition`.
        """
        return tuple(
            (start, s.slots, s.memory_gb)
            for start, s in zip(self.starts, self.slices, strict=True)
        )

    def occupied_cells(self, index: int) -> range:
        """Grid cells ``[start, start+slots)`` occupied by slice ``index``."""
        return range(self.starts[index], self.starts[index] + self.slices[index].slots)

    def __str__(self) -> str:  # pragma: no cover - repr sugar
        body = " + ".join(
            f"{s.name}@{start}" for start, s in zip(self.starts, self.slices, strict=True)
        )
        return f"cfg{self.config_id}[{body}]"


def _mk(config_id: int, *slices: SliceType) -> Partition:
    return Partition(config_id=config_id, slices=tuple(slices))


# Fig. 1 — the 12 configurations of an A100-40GB considered by the paper.
MIG_CONFIGS: Dict[int, Partition] = {
    1: _mk(1, S7_40),
    2: _mk(2, S4_20, S3_20),
    3: _mk(3, S4_20, S2_10, S1_10),
    4: _mk(4, S4_20, S1_5, S1_5, S1_10),
    5: _mk(5, S3_20, S3_20),  # note: 1-slot "hole" (6 of 7 slots used)
    6: _mk(6, S2_10, S2_10, S3_20),
    7: _mk(7, S2_10, S1_5, S1_5, S3_20),
    8: _mk(8, S1_5, S1_5, S1_5, S1_5, S3_20),
    9: _mk(9, S2_10, S2_10, S2_10, S1_10),
    10: _mk(10, S2_10, S2_10, S1_5, S1_5, S1_10),
    11: _mk(11, S2_10, S1_5, S1_5, S1_5, S1_5, S1_10),
    12: _mk(12, S1_5, S1_5, S1_5, S1_5, S1_5, S1_5, S1_10),
}

NUM_CONFIGS = len(MIG_CONFIGS)


def config(config_id: int) -> Partition:
    """Return the partition for a 1-based Fig. 1 configuration id."""
    try:
        return MIG_CONFIGS[config_id]
    except KeyError as e:  # pragma: no cover - defensive
        raise KeyError(
            f"unknown MIG config {config_id}; valid ids {sorted(MIG_CONFIGS)}"
        ) from e


def config_ids() -> Sequence[int]:
    return tuple(sorted(MIG_CONFIGS))


# ----------------------------------------------------------------------
# A30-class device (24 GB, 4 compute slots): the second fleet profile.
# NVIDIA's A30 MIG geometry: 1g.6gb, 2g.12gb, 4g.24gb; four valid layouts.

A30_S1_6 = SliceType(1, 6)
A30_S2_12 = SliceType(2, 12)
A30_S4_24 = SliceType(4, 24)

A30_CONFIGS: Dict[int, Partition] = {
    1: _mk(1, A30_S4_24),
    2: _mk(2, A30_S2_12, A30_S2_12),
    3: _mk(3, A30_S2_12, A30_S1_6, A30_S1_6),
    4: _mk(4, A30_S1_6, A30_S1_6, A30_S1_6, A30_S1_6),
}


@dataclasses.dataclass(frozen=True)
class TransitionPlan:
    """What a reconfiguration ``old -> new`` does to placed slice instances.

    A slice instance *survives* when the identical ``(start, slots,
    memory_gb)`` placement exists in both configurations — the physical GPU
    instance is untouched and jobs on it keep running.  Everything else is
    destroyed (old indices) or created (new indices) and stalls for the
    §IV-D-3 repartition penalty.

    ``surviving`` maps old slice index -> new slice index (survivor identity
    across the index renumbering).  ``stalled_slots`` counts the grid cells
    touched by the rebuild (cells of destroyed ∪ cells of created) — the
    stall footprint the simulator charges and telemetry reports.
    """

    old_config_id: int
    new_config_id: int
    surviving: Tuple[Tuple[int, int], ...]  # (old index, new index) pairs
    destroyed: Tuple[int, ...]  # old slice indices torn down
    created: Tuple[int, ...]  # new slice indices built
    stalled_slots: int

    @property
    def survivor_map(self) -> Dict[int, int]:
        """``surviving`` as an old-index -> new-index dict."""
        return dict(self.surviving)

    @property
    def full_turnover(self) -> bool:
        """True when no slice instance survives (drain-equivalent switch)."""
        return not self.surviving


def transition(old: Partition, new: Partition) -> TransitionPlan:
    """Plan the partial reconfiguration ``old -> new`` (DESIGN.md §7).

    Matches placed slice instances by identity (same start offset, compute
    width, and memory): matches survive with their jobs, the rest are
    destroyed/created.  ``transition(p, p)`` is the identity plan (everything
    survives, nothing stalls); a plan with no survivors is exactly the
    legacy full-drain model.
    """
    old_by_key = {key: i for i, key in enumerate(old.slice_instances())}
    surviving: List[Tuple[int, int]] = []
    created: List[int] = []
    for j, key in enumerate(new.slice_instances()):
        i = old_by_key.get(key)
        if i is not None:
            surviving.append((i, j))
        else:
            created.append(j)
    matched_old = {i for i, _ in surviving}
    destroyed = tuple(i for i in range(old.num_slices) if i not in matched_old)
    cells = set()
    for i in destroyed:
        cells.update(old.occupied_cells(i))
    for j in created:
        cells.update(new.occupied_cells(j))
    return TransitionPlan(
        old_config_id=old.config_id,
        new_config_id=new.config_id,
        surviving=tuple(surviving),
        destroyed=destroyed,
        created=tuple(created),
        stalled_slots=len(cells),
    )


# ----------------------------------------------------------------------
# Free-slot geometry and the fragmentation ratio (DESIGN.md §9).
#
# A serving fleet cares not about *how many* slots are free but about the
# largest instance the free region can still host: seven free slots split
# 1+2+1+2+1 across placement holes cannot place a 4g slice.  Following the
# fragmentation-aware MIG literature we measure this as a ratio in [0, 1]:
# 0 when the free capacity is fully usable (or there is none), approaching
# 1 as alignment holes shred it.


@dataclasses.dataclass(frozen=True)
class FreeSlotGeometry:
    """The free region of a slot grid, as maximal contiguous runs.

    A grid cell is *free* when no occupied slice covers it — cells of
    unoccupied slice instances count as free (a repartition may rebuild
    them), as do placement holes outside every slice (config 5's slot 3).

    ``slice_sizes`` is the device's placeable instance vocabulary (an A30
    has no 3g slice); it bounds :attr:`max_placeable_slots` and therefore
    the fragmentation ratio.
    """

    total_slots: int
    runs: Tuple[Tuple[int, int], ...]  # maximal free runs as (start, length)
    slice_sizes: Tuple[int, ...] = ALL_SLICE_SIZES

    @property
    def free_slots(self) -> int:
        """Total free grid cells (sum of run lengths)."""
        return sum(length for _, length in self.runs)

    def placeable_starts(self, slots: int) -> Tuple[int, ...]:
        """Aligned start offsets where a ``slots``-wide instance fits."""
        a = placement_alignment(slots)
        out: List[int] = []
        for start, length in self.runs:
            s = ((start + a - 1) // a) * a
            while s + slots <= start + length:
                out.append(s)
                s += a
        return tuple(out)

    @property
    def max_placeable_slots(self) -> int:
        """Largest placeable instance (0 when nothing fits anywhere)."""
        best = 0
        for slots in self.slice_sizes:
            if slots > best and self.placeable_starts(slots):
                best = slots
        return best

    @property
    def fragmentation(self) -> float:
        """``1 - max_placeable / free`` in [0, 1]; 0 when nothing is free.

        0 means the free capacity is fully usable as one instance (an empty
        or a fully-occupied device both score 0); it grows as placement
        alignment shreds the free cells into runs too small or misaligned
        for the larger slice classes.
        """
        free = self.free_slots
        if free == 0:
            return 0.0
        return 1.0 - self.max_placeable_slots / free


def table_slice_sizes(configs: Dict[int, Partition]) -> Tuple[int, ...]:
    """Sorted distinct slice widths a device's partition table can place."""
    return tuple(sorted({s.slots for p in configs.values() for s in p.slices}))


def free_slot_geometry(
    partition: Partition,
    occupied_slices: Sequence[int],
    *,
    total_slots: int,
    slice_sizes: Optional[Sequence[int]] = None,
) -> FreeSlotGeometry:
    """Free-slot geometry of ``partition`` with the given slices occupied.

    ``occupied_slices`` are indices into ``partition.slices`` (an invalid
    index raises).  Free cells are everything else on the ``total_slots``
    grid: unoccupied slice instances and placement holes alike.
    """
    busy = set()
    for i in occupied_slices:
        if not 0 <= i < partition.num_slices:
            raise IndexError(
                f"occupied slice index {i} out of range for {partition}"
            )
        busy.update(partition.occupied_cells(i))
    sizes = (
        tuple(sorted(slice_sizes))
        if slice_sizes is not None
        else tuple(s for s in ALL_SLICE_SIZES if s <= total_slots)
    )
    runs: List[Tuple[int, int]] = []
    run_start: Optional[int] = None
    for cell in range(total_slots):
        if cell in busy:
            if run_start is not None:
                runs.append((run_start, cell - run_start))
                run_start = None
        elif run_start is None:
            run_start = cell
    if run_start is not None:
        runs.append((run_start, total_slots - run_start))
    return FreeSlotGeometry(
        total_slots=total_slots, runs=tuple(runs), slice_sizes=sizes
    )


def fleet_fragmentation(geometries: Sequence[FreeSlotGeometry]) -> float:
    """Free-capacity-weighted fleet fragmentation ratio in [0, 1].

    ``1 - sum(max placeable) / sum(free)`` over the fleet — equivalently
    the per-device ratios weighted by each device's free slots, so a large
    idle device dominates a shredded small one.  0 when nothing is free.
    """
    free = sum(g.free_slots for g in geometries)
    if free == 0:
        return 0.0
    placeable = sum(g.max_placeable_slots for g in geometries)
    return 1.0 - placeable / free


def validate_config_table(
    configs: Dict[int, Partition],
    max_slots: int,
    max_memory_gb: int,
    max_1g10_slices: int | None = None,
    name: str | None = None,
) -> None:
    """Sanity-check a device's partition table (invoked at import, cheap).

    Besides the capacity checks, verifies every configuration is *placement
    valid* on the device's slot grid: starts respect the NVIDIA alignment
    rule (:func:`placement_alignment`), slices stay inside the grid, and no
    two slices overlap — the preconditions the :func:`transition` instance
    matching relies on.

    ``name`` identifies the device profile (or table) in every error so a
    fleet-config failure points at the offending hardware entry, not just a
    bare config id that is ambiguous across per-profile tables.
    """
    where = f"{name} table, " if name else ""
    for cid, part in configs.items():
        ctx = f"{where}config {cid}"
        if part.config_id != cid:
            raise AssertionError(f"{ctx}: config id mismatch ({part.config_id})")
        if part.total_slots > max_slots:
            raise AssertionError(f"{ctx} exceeds {max_slots} slots")
        if part.total_memory_gb > max_memory_gb:
            raise AssertionError(f"{ctx} exceeds {max_memory_gb}GB")
        if max_1g10_slices is not None:
            n_1g10 = sum(1 for s in part.slices if s == S1_10)
            if n_1g10 > max_1g10_slices:
                raise AssertionError(f"{ctx} has {n_1g10} 1g.10gb slices")
        occupied: set = set()
        for i, (start, s) in enumerate(zip(part.starts, part.slices, strict=True)):
            if start % placement_alignment(s.slots) != 0:
                raise AssertionError(
                    f"{ctx} slice {i} ({s.name}) starts at {start}, "
                    f"violating the {placement_alignment(s.slots)}-slot "
                    "placement alignment"
                )
            cells = set(part.occupied_cells(i))
            if start < 0 or start + s.slots > max_slots:
                raise AssertionError(
                    f"{ctx} slice {i} ({s.name}@{start}) leaves the "
                    f"{max_slots}-slot grid"
                )
            if occupied & cells:
                raise AssertionError(
                    f"{ctx} slice {i} ({s.name}@{start}) overlaps "
                    "another slice"
                )
            occupied |= cells


# A100 Fig. 1 table: at most one 1g.10gb slice per configuration (paper §III-A)
validate_config_table(MIG_CONFIGS, TOTAL_SLOTS, 40, max_1g10_slices=1, name="A100 Fig. 1")
validate_config_table(A30_CONFIGS, 4, 24, name="A30")
