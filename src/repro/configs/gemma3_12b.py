"""gemma3-12b [dense]: 48L d=3840 16H (GQA kv=8) d_ff=15360 vocab=262144.

[hf:google/gemma-3-1b-pt family; unverified] — 5:1 local:global attention,
sliding window 1024, qk-norm, tied embeddings, 128k context.  Runs
``long_500k`` (local layers dominate; global layers are linear-cost at
decode) — DESIGN.md §4.
"""
import dataclasses
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="gemma3-12b",
    family="dense",
    n_layers=48,
    d_model=3840,
    n_heads=16,
    n_kv_heads=8,
    head_dim=256,
    d_ff=15360,
    vocab_size=262144,
    activation="geglu",
    norm="rmsnorm",
    rope_theta=1_000_000.0,
    qk_norm=True,
    tie_embeddings=True,
    sliding_window=1024,
    local_global_ratio=(5, 1),
    max_seq_len=524_288,
)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=6, d_model=64, n_heads=2, n_kv_heads=1, head_dim=32,
    d_ff=256, vocab_size=256, sliding_window=64, max_seq_len=512,
)
