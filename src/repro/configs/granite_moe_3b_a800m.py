"""granite-moe-3b-a800m [moe]: 32L d=1536 24H (GQA kv=8) d_ff_expert=512
vocab=49155, MoE 40 experts top-8, every layer.

[hf:ibm-granite/granite-3.0-1b-a400m-base family; hf] — SwiGLU experts.
Pure full attention: ``long_500k`` skipped (DESIGN.md §4).
"""
import dataclasses
from repro.models.config import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="granite-moe-3b-a800m",
    family="moe",
    n_layers=32,
    d_model=1536,
    n_heads=24,
    n_kv_heads=8,
    d_ff=0,  # every MLP is MoE
    vocab_size=49155,
    activation="swiglu",
    norm="rmsnorm",
    moe=MoEConfig(num_experts=40, top_k=8, d_ff_expert=512, every_k_layers=1),
    tie_embeddings=True,
    max_seq_len=32_768,
)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, vocab_size=256,
    moe=MoEConfig(num_experts=4, top_k=2, d_ff_expert=64, every_k_layers=1),
    max_seq_len=512,
)
