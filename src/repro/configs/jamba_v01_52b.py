"""jamba-v0.1-52b [hybrid]: 32L d=4096 32H (GQA kv=8) d_ff=14336 vocab=65536,
Mamba+attention 1:7 interleave, MoE 16 experts top-2 every other layer.

[arXiv:2403.19887; hf] — ``long_500k``-capable (Mamba-dominant, O(1) state;
the 4 attention layers keep linear-cost decode).
"""
import dataclasses
from repro.models.config import ArchConfig, MambaConfig, MoEConfig

CONFIG = ArchConfig(
    name="jamba-v0.1-52b",
    family="hybrid",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab_size=65536,
    activation="swiglu",
    norm="rmsnorm",
    block_pattern="jamba",
    attn_every_k=8,  # 1:7 attention:mamba
    mamba=MambaConfig(d_state=16, d_conv=4, expand=2),
    moe=MoEConfig(num_experts=16, top_k=2, d_ff_expert=14336, every_k_layers=2),
    max_seq_len=524_288,
)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=8, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
    vocab_size=256,
    moe=MoEConfig(num_experts=4, top_k=2, d_ff_expert=128, every_k_layers=2),
    max_seq_len=512,
)
