"""nemotron-4-340b [dense]: 96L d=18432 96H (GQA kv=8) d_ff=73728 vocab=256000.

[arXiv:2402.16819; unverified] — squared-ReLU MLP, GQA.  Pure full attention:
``long_500k`` skipped (DESIGN.md §4).
"""
import dataclasses
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="nemotron-4-340b",
    family="dense",
    n_layers=96,
    d_model=18432,
    n_heads=96,
    n_kv_heads=8,
    d_ff=73728,
    vocab_size=256000,
    activation="sq_relu",
    norm="layernorm",
    rope_theta=10_000.0,
    max_seq_len=32_768,
)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=4, d_model=128, n_heads=8, n_kv_heads=2, d_ff=512,
    vocab_size=256, max_seq_len=512,
)
