"""The paper's own experimental configuration (§V-A) as a config object."""

import dataclasses

from repro.core.power import A100_250W
from repro.core.workload import WorkloadSpec


@dataclasses.dataclass(frozen=True)
class PaperA100Config:
    """A100-40GB, 250W cap, §V-A workload; scheduler EDF-SS (restricted)."""

    scheduler: str = "EDF-SS"
    workload: WorkloadSpec = dataclasses.field(default_factory=WorkloadSpec)
    static_benchmark_config: int = 3  # §V-A: best fixed configuration
    day_config: int = 6  # §V-A: day-time (5:00-17:00)
    night_config: int = 2  # §V-A: night-time
    repartition_penalty_s: float = 4.0  # §IV-D-3
    in_config_iterations: int = 250  # §V-A
    repartition_iterations: int = 500  # §V-A

    @property
    def power_model(self):
        return A100_250W


CONFIG = PaperA100Config()
