"""gemma3-1b [dense]: 26L d=1152 4H (GQA kv=1) d_ff=6912 vocab=262144.

[hf:google/gemma-3-1b-pt; unverified] — 5:1 local:global, window 512,
qk-norm, tied embeddings.  Runs ``long_500k`` (DESIGN.md §4).
26 layers: 4 full (5L+1G) units + a 2-layer tail handled unscanned.
"""
import dataclasses
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="gemma3-1b",
    family="dense",
    n_layers=26,
    d_model=1152,
    n_heads=4,
    n_kv_heads=1,
    head_dim=256,
    d_ff=6912,
    vocab_size=262144,
    activation="geglu",
    norm="rmsnorm",
    rope_theta=1_000_000.0,
    qk_norm=True,
    tie_embeddings=True,
    sliding_window=512,
    local_global_ratio=(5, 1),
    max_seq_len=524_288,
)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=13, d_model=64, n_heads=2, n_kv_heads=1, head_dim=32,
    d_ff=256, vocab_size=256, sliding_window=64, max_seq_len=512,
)
