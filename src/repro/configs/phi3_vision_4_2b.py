"""phi-3-vision-4.2b [vlm]: 32L d=3072 32H (MHA kv=32) d_ff=8192 vocab=32064,
phi3-mini backbone + CLIP frontend (STUB: precomputed patch embeddings).

[hf:microsoft/Phi-3-vision-128k-instruct; hf] — the modality frontend is a
stub per the brief: ``input_specs`` provides (B, 576, 3072) patch embeddings
prepended to the text sequence.  Pure full attention: ``long_500k`` skipped.
"""
import dataclasses
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="phi-3-vision-4.2b",
    family="vlm",
    n_layers=32,
    d_model=3072,
    n_heads=32,
    n_kv_heads=32,
    d_ff=8192,
    vocab_size=32064,
    activation="swiglu",
    norm="rmsnorm",
    rope_theta=10_000.0,
    vision_tokens=576,
    max_seq_len=131_072,
)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=128,
    vocab_size=256, vision_tokens=16, max_seq_len=512,
)
