"""whisper-base [audio]: enc-dec, 6L each, d=512 8H d_ff=2048 vocab=51865.

[arXiv:2212.04356; unverified] — conv frontend is a STUB: ``input_specs``
provides precomputed (B, 1500, 512) frame embeddings.  Enc-dec: decode shapes
use decoder self-attn KV + cross-attention; no 500k decode by construction
(DESIGN.md §4).
"""
import dataclasses
from repro.models.config import ArchConfig, EncoderConfig

CONFIG = ArchConfig(
    name="whisper-base",
    family="audio",
    n_layers=6,
    d_model=512,
    n_heads=8,
    n_kv_heads=8,
    d_ff=2048,
    vocab_size=51865,
    activation="gelu",
    norm="layernorm",
    encoder=EncoderConfig(n_layers=6, n_frames=1500),
    max_seq_len=32_768,
)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=2, d_model=64, n_heads=2, n_kv_heads=2, d_ff=128,
    vocab_size=256, encoder=EncoderConfig(n_layers=2, n_frames=32),
    max_seq_len=512,
)
