"""xlstm-350m [ssm]: 24L d=1024 4H, sLSTM + mLSTM blocks (xLSTM[7:1]).

[arXiv:2405.04517; unverified] — d_ff=0 (blocks are self-contained),
vocab 50304.  ``long_500k``-capable (O(1) recurrent state).
"""
import dataclasses
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="xlstm-350m",
    family="ssm",
    n_layers=24,
    d_model=1024,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,
    vocab_size=50304,
    block_pattern="xlstm",
    xlstm_slstm_every=8,  # xLSTM[7:1]
    norm="rmsnorm",
    tie_embeddings=False,
    max_seq_len=524_288,
)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=8, d_model=64, n_heads=2, vocab_size=256, max_seq_len=512
)
