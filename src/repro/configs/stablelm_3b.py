"""stablelm-3b [dense]: 32L d=2560 32H (MHA kv=32) d_ff=6912 vocab=50304.

[hf:stabilityai/stablelm-2-1_6b family; unverified] — LayerNorm + SwiGLU.
Pure full attention: ``long_500k`` skipped (DESIGN.md §4).
"""
import dataclasses
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="stablelm-3b",
    family="dense",
    n_layers=32,
    d_model=2560,
    n_heads=32,
    n_kv_heads=32,
    d_ff=6912,
    vocab_size=50304,
    activation="swiglu",
    norm="layernorm",
    rope_theta=10_000.0,
    max_seq_len=32_768,
)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=4, d_model=128, n_heads=4, n_kv_heads=4, d_ff=384,
    vocab_size=256, max_seq_len=512,
)
