"""Assigned architecture configs (exact public-literature shapes) + registry.

``get_config(name)`` returns the full production config; ``smoke_config(name)``
returns a reduced same-family config for CPU smoke tests (small layers/width,
few experts, tiny vocab — per the assignment brief the full configs are only
exercised via the dry-run).
"""

from __future__ import annotations

import dataclasses
import importlib
from typing import Dict, List

from repro.models.config import ArchConfig

ARCH_IDS = [
    "xlstm_350m",
    "nemotron_4_340b",
    "gemma3_12b",
    "gemma3_1b",
    "stablelm_3b",
    "granite_moe_3b_a800m",
    "mixtral_8x7b",
    "whisper_base",
    "jamba_v01_52b",
    "phi3_vision_4_2b",
]

# canonical external ids (assignment spelling) -> module names
ALIASES = {
    "xlstm-350m": "xlstm_350m",
    "nemotron-4-340b": "nemotron_4_340b",
    "gemma3-12b": "gemma3_12b",
    "gemma3-1b": "gemma3_1b",
    "stablelm-3b": "stablelm_3b",
    "granite-moe-3b-a800m": "granite_moe_3b_a800m",
    "mixtral-8x7b": "mixtral_8x7b",
    "whisper-base": "whisper_base",
    "jamba-v0.1-52b": "jamba_v01_52b",
    "phi-3-vision-4.2b": "phi3_vision_4_2b",
}


def get_config(name: str) -> ArchConfig:
    mod_name = ALIASES.get(name, name).replace("-", "_").replace(".", "_")
    mod = importlib.import_module(f"repro.configs.{mod_name}")
    return mod.CONFIG


def smoke_config(name: str) -> ArchConfig:
    mod_name = ALIASES.get(name, name).replace("-", "_").replace(".", "_")
    mod = importlib.import_module(f"repro.configs.{mod_name}")
    return mod.SMOKE


def all_configs() -> Dict[str, ArchConfig]:
    return {n: get_config(n) for n in ARCH_IDS}
