"""mixtral-8x7b [moe]: 32L d=4096 32H (GQA kv=8) d_ff=14336 vocab=32000,
MoE 8 experts top-2 every layer, sliding-window attention (4096).

[arXiv:2401.04088; hf] — SWA makes it ``long_500k``-capable with ring-buffer
KV caches (DESIGN.md §4).
"""
import dataclasses
from repro.models.config import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="mixtral-8x7b",
    family="moe",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=0,  # every MLP is MoE (d_ff_expert below)
    vocab_size=32000,
    activation="swiglu",
    norm="rmsnorm",
    rope_theta=1_000_000.0,
    sliding_window=4096,
    moe=MoEConfig(num_experts=8, top_k=2, d_ff_expert=14336, every_k_layers=1),
    max_seq_len=524_288,
)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, vocab_size=256,
    sliding_window=64,
    moe=MoEConfig(num_experts=4, top_k=2, d_ff_expert=128, every_k_layers=1),
    max_seq_len=512,
)
