"""Learning-rate schedules (pure functions of the step)."""

from __future__ import annotations

import jax.numpy as jnp

__all__ = ["cosine_schedule", "linear_warmup_cosine"]


def cosine_schedule(base_lr: float, total_steps: int, final_frac: float = 0.1):
    def lr(step):
        frac = jnp.clip(step / max(total_steps, 1), 0.0, 1.0)
        cos = 0.5 * (1.0 + jnp.cos(jnp.pi * frac))
        return base_lr * (final_frac + (1.0 - final_frac) * cos)

    return lr


def linear_warmup_cosine(
    base_lr: float, warmup_steps: int, total_steps: int, final_frac: float = 0.1
):
    cos = cosine_schedule(base_lr, max(total_steps - warmup_steps, 1), final_frac)

    def lr(step):
        warm = base_lr * jnp.minimum(step / max(warmup_steps, 1), 1.0)
        return jnp.where(step < warmup_steps, warm, cos(step - warmup_steps))

    return lr
