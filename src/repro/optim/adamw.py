"""AdamW on parameter pytrees.

Production knobs for the large assigned archs:
* ``state_dtype="bfloat16"`` halves optimizer memory (m, v in bf16) — used by
  nemotron-4-340b to fit a v5e's 16 GB HBM (see EXPERIMENTS.md §Dry-run),
* global-norm gradient clipping,
* decoupled weight decay, schedule passed as a function of step.

Optimizer state inherits each parameter's sharding (same tree structure), so
FSDP/TP shards m and v alongside the weights — ZeRO-style by construction.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

__all__ = ["AdamWConfig", "OptState", "AdamW"]


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: Any = 3e-4  # float or Callable[step] -> float
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip_norm: Optional[float] = 1.0
    state_dtype: Optional[str] = None  # None = match param dtype promoted fp32


class OptState(NamedTuple):
    m: Any
    v: Any
    step: jnp.ndarray


class AdamW:
    def __init__(self, cfg: AdamWConfig) -> None:
        self.cfg = cfg

    def _state_dtype(self, leaf: jnp.ndarray) -> jnp.dtype:
        if self.cfg.state_dtype is not None:
            return jnp.dtype(self.cfg.state_dtype)
        return jnp.float32

    def init(self, params: Any) -> OptState:
        zeros = lambda p: jnp.zeros(p.shape, self._state_dtype(p))
        return OptState(
            m=jax.tree_util.tree_map(zeros, params),
            v=jax.tree_util.tree_map(zeros, params),
            step=jnp.zeros((), jnp.int32),
        )

    def _lr(self, step: jnp.ndarray) -> jnp.ndarray:
        if callable(self.cfg.lr):
            return self.cfg.lr(step)
        return jnp.asarray(self.cfg.lr, jnp.float32)

    def update(self, grads: Any, state: OptState, params: Any):
        cfg = self.cfg
        step = state.step + 1

        if cfg.grad_clip_norm is not None:
            leaves = jax.tree_util.tree_leaves(grads)
            gnorm = jnp.sqrt(
                sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in leaves)
            )
            scale = jnp.minimum(1.0, cfg.grad_clip_norm / (gnorm + 1e-9))
            grads = jax.tree_util.tree_map(
                lambda g: (g.astype(jnp.float32) * scale), grads
            )
        else:
            grads = jax.tree_util.tree_map(lambda g: g.astype(jnp.float32), grads)

        bc1 = 1.0 - cfg.b1**step.astype(jnp.float32)
        bc2 = 1.0 - cfg.b2**step.astype(jnp.float32)
        lr = self._lr(step)

        def upd(p, g, m, v):
            mf = m.astype(jnp.float32) * cfg.b1 + (1 - cfg.b1) * g
            vf = v.astype(jnp.float32) * cfg.b2 + (1 - cfg.b2) * g * g
            mhat = mf / bc1
            vhat = vf / bc2
            delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
            if cfg.weight_decay > 0.0 and p.ndim >= 2:  # no decay on norms/bias
                delta = delta + cfg.weight_decay * p.astype(jnp.float32)
            new_p = p.astype(jnp.float32) - lr * delta
            return (
                new_p.astype(p.dtype),
                mf.astype(m.dtype),
                vf.astype(v.dtype),
            )

        # flatten against the params treedef rather than tree_map + is_leaf
        # tuple-sniffing: param trees that themselves contain tuples (e.g. the
        # DQN's list of (w, b) layers) would otherwise be mis-split
        p_flat, treedef = jax.tree_util.tree_flatten(params)
        g_flat = jax.tree_util.tree_leaves(grads)
        m_flat = jax.tree_util.tree_leaves(state.m)
        v_flat = jax.tree_util.tree_leaves(state.v)
        triples = [upd(p, g, m, v) for p, g, m, v in zip(p_flat, g_flat, m_flat, v_flat, strict=True)]
        new_params = treedef.unflatten([t[0] for t in triples])
        new_m = treedef.unflatten([t[1] for t in triples])
        new_v = treedef.unflatten([t[2] for t in triples])
        return new_params, OptState(m=new_m, v=new_v, step=step)
