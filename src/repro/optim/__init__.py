"""Optimizer substrate (no external deps): AdamW + schedules + clipping."""

from repro.optim.adamw import AdamW, AdamWConfig, OptState
from repro.optim.schedule import cosine_schedule, linear_warmup_cosine

__all__ = [
    "AdamW",
    "AdamWConfig",
    "OptState",
    "cosine_schedule",
    "linear_warmup_cosine",
]
