import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: prove every (arch x shape x mesh) cell lowers, shards
and compiles on the production mesh — and extract its roofline terms.

The two lines above MUST stay first: jax locks the device count at first
init, and the dry-run needs 512 placeholder host devices for the 2x16x16
multi-pod mesh.  (Smoke tests/benches import repro.* without this module and
keep seeing 1 device.)

Per cell this produces (cached incrementally under artifacts/dryrun/):
* compile success + ``memory_analysis()``   (does it fit 16 GB/chip?)
* ``cost_analysis()`` FLOPs/bytes           (§Roofline compute/memory terms)
* collective bytes parsed from the compiled HLO (§Roofline collective term)

``lax.scan`` bodies are counted ONCE by XLA's cost analysis, so scanned
models would under-report by ~n_layers.  The extractor therefore also lowers
two unscanned mini-models (1 and 2 pattern units) and composites:
``total = outer + unit x repeats`` with ``unit = mini2 - mini1`` — exact for
per-layer costs, and it localizes collectives correctly (gradient
all-reduces of a unit's params appear in the diff).  See EXPERIMENTS.md
§Dry-run for the methodology notes.
"""

import argparse
import dataclasses
import json
import re
import time
import traceback
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.data.pipeline import make_batch_specs
from repro.distributed.sharding import (
    batch_shardings,
    cache_shardings,
    param_shardings,
)
from repro.distributed.step import make_prefill_step, make_serve_step, make_train_step
from repro.launch.mesh import make_production_mesh, set_ambient_mesh
from repro.launch.shapes import SHAPES, ShapeSpec, accum_steps_for, cell_applicable
from repro.models import abstract_params, init_cache
from repro.models.config import ArchConfig
from repro.optim import AdamW, AdamWConfig

ARTIFACTS = os.path.join(os.path.dirname(__file__), "..", "..", "..", "artifacts", "dryrun")

_COLLECTIVE_RE = re.compile(
    r"\b(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
)
_SHAPE_RE = re.compile(r"(f32|bf16|f16|s32|u32|s8|u8|pred|s64|f64)\[([0-9,]*)\]")
_DTYPE_BYTES = {
    "f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4,
    "s8": 1, "u8": 1, "pred": 1, "s64": 8, "f64": 8,
}


def parse_collective_bytes(hlo_text: str) -> Dict[str, float]:
    """Sum result-shape bytes of every collective op (per-device program)."""
    out: Dict[str, float] = {}
    for line in hlo_text.splitlines():
        m = _COLLECTIVE_RE.search(line)
        if not m or "=" not in line:
            continue
        kind = m.group(1)
        lhs = line.split("=")[0]
        # result shape(s) appear on the lhs of "name = shape op(...)"
        rhs_head = line.split("=", 1)[1]
        shapes = _SHAPE_RE.findall(rhs_head.split(m.group(1))[0])
        if not shapes:
            shapes = _SHAPE_RE.findall(lhs)
        nbytes = 0.0
        for dt, dims in shapes:
            numel = 1
            if dims:
                for d in dims.split(","):
                    if d:
                        numel *= int(d)
            nbytes += numel * _DTYPE_BYTES[dt]
        out[kind] = out.get(kind, 0.0) + nbytes
    return out


def runtime_config(arch: str, for_cost: bool = False, repeats: Optional[int] = None) -> ArchConfig:
    cfg = get_config(arch)
    if not for_cost:
        return dataclasses.replace(cfg, scan_layers=True, remat="block")
    unit_len = len(cfg.pattern_unit())
    assert repeats is not None
    changes: Dict[str, Any] = dict(
        n_layers=unit_len * repeats, scan_layers=False, remat="none"
    )
    if cfg.encoder is not None:
        changes["encoder"] = dataclasses.replace(cfg.encoder, n_layers=repeats)
    return dataclasses.replace(cfg, **changes)


def make_optimizer(cfg: ArchConfig) -> AdamW:
    # bf16 optimizer states for the giant models (EXPERIMENTS.md memory table)
    state_dtype = "bfloat16" if cfg.d_model >= 8_000 else None
    return AdamW(AdamWConfig(lr=3e-4, state_dtype=state_dtype))


# --------------------------- abstract inputs ------------------------------


def input_specs(arch: str, shape: ShapeSpec, mesh, cfg: Optional[ArchConfig] = None):
    """ShapeDtypeStruct stand-ins + shardings for one cell (no allocation)."""
    cfg = cfg or runtime_config(arch)
    params_abs = abstract_params(cfg)
    # resident-weight (serve) sharding only pays when the batch amortises the
    # per-device weight reads; at batch 1 (long_500k) 2-D sharding reads 16x
    # less weight per device and the activation psums are tiny (§Perf log)
    serve_mode = shape.kind != "train" and shape.global_batch >= 32
    p_shard = param_shardings(
        params_abs, mesh, mode="serve" if serve_mode else "train"
    )

    if shape.kind == "train":
        opt = make_optimizer(cfg)
        opt_abs = jax.eval_shape(opt.init, params_abs)
        o_shard = param_shardings_like(opt_abs, p_shard)
        batch = make_batch_specs(cfg, shape.global_batch, shape.seq_len, True)
        b_shard = batch_shardings(batch, mesh)
        return (params_abs, opt_abs, batch), (p_shard, o_shard, b_shard), opt
    if shape.kind == "prefill":
        batch = make_batch_specs(cfg, shape.global_batch, shape.seq_len, False)
        b_shard = batch_shardings(batch, mesh)
        return (params_abs, batch), (p_shard, b_shard), None
    # decode
    cache_abs = jax.eval_shape(lambda: init_cache(cfg, shape.global_batch, shape.seq_len))
    c_shard = cache_shardings(cache_abs, mesh, shape.global_batch)
    token = jax.ShapeDtypeStruct((shape.global_batch, 1), jnp.int32)
    index = jax.ShapeDtypeStruct((), jnp.int32)
    from jax.sharding import NamedSharding, PartitionSpec as P

    t_shard = jax.tree_util.tree_map(
        lambda _: NamedSharding(mesh, P()), token
    )
    i_shard = NamedSharding(mesh, P())
    args = [params_abs, cache_abs, token, index]
    shards = [p_shard, c_shard, t_shard, i_shard]
    if cfg.encoder is not None:
        enc = jax.ShapeDtypeStruct(
            (shape.global_batch, cfg.encoder.n_frames, cfg.d_model), jnp.bfloat16
        )
        args.append(enc)
        shards.append(NamedSharding(mesh, P()))
    return tuple(args), tuple(shards), None


def param_shardings_like(opt_abs, p_shard):
    """Optimizer state shardings: m/v mirror the params; step replicated."""
    import jax.tree_util as jtu
    from jax.sharding import NamedSharding, PartitionSpec as P

    mesh = jtu.tree_leaves(p_shard)[0].mesh
    flat_p = jtu.tree_leaves(p_shard)

    def build(tree):
        leaves = jtu.tree_leaves(tree)
        # m and v have the same structure as params
        return jtu.tree_unflatten(jtu.tree_structure(tree), flat_p[: len(leaves)])

    return type(opt_abs)(
        m=build(opt_abs.m),
        v=build(opt_abs.v),
        step=NamedSharding(mesh, P()),
    )


# ------------------------------ lowering -----------------------------------


def lower_cell(
    arch: str,
    shape: ShapeSpec,
    mesh,
    cfg: Optional[ArchConfig] = None,
    donate: bool = True,
    compile_: bool = True,
) -> Dict[str, Any]:
    cfg = cfg or runtime_config(arch)
    t0 = time.time()
    args, shards, opt = input_specs(arch, shape, mesh, cfg)

    if shape.kind == "train":
        accum = accum_steps_for(arch, shape, int(np.prod([mesh.shape[a] for a in mesh.axis_names if a != "model"])))
        if os.environ.get("REPRO_ACCUM_OVERRIDE"):
            accum = int(os.environ["REPRO_ACCUM_OVERRIDE"])
        if not cfg.scan_layers:  # cost mode: no accumulation scan
            accum = 1
        g_dt = "bfloat16" if cfg.d_model >= 8_000 else "float32"
        step = make_train_step(
            cfg, opt, accum_steps=accum, impl="ref", grad_accum_dtype=g_dt
        )
        jitted = jax.jit(
            step,
            in_shardings=shards,
            donate_argnums=(0, 1) if donate else (),
        )
    elif shape.kind == "prefill":
        step = make_prefill_step(cfg, impl="ref")
        jitted = jax.jit(step, in_shardings=shards)
    else:
        step = make_serve_step(cfg, impl="ref")
        jitted = jax.jit(
            step, in_shardings=shards, donate_argnums=(1,) if donate else ()
        )

    set_ambient_mesh(mesh)  # populates the abstract mesh for hints
    with mesh:
        lowered = jitted.lower(*args)
        rec: Dict[str, Any] = {"lower_seconds": time.time() - t0}
        if compile_:
            t1 = time.time()
            compiled = lowered.compile()
            rec["compile_seconds"] = time.time() - t1
            mem = compiled.memory_analysis()
            if mem is not None:
                for attr in (
                    "argument_size_in_bytes",
                    "output_size_in_bytes",
                    "temp_size_in_bytes",
                    "generated_code_size_in_bytes",
                    "alias_size_in_bytes",
                ):
                    rec[attr] = getattr(mem, attr, None)
            cost = compiled.cost_analysis()
            if isinstance(cost, list):
                cost = cost[0]
            rec["flops"] = float(cost.get("flops", 0.0)) if cost else None
            rec["bytes_accessed"] = float(cost.get("bytes accessed", 0.0)) if cost else None
            rec["collectives"] = parse_collective_bytes(compiled.as_text())
    return rec


def composite_cost(arch: str, shape: ShapeSpec, mesh) -> Dict[str, Any]:
    """Scan-free cost: lower 0- and 1-unit mini-models, composite per-unit.

    mini0 = embed + head only (compiles in seconds even for 340B shapes);
    unit = mini1 - mini0; total = mini0 + unit x repeats.
    """
    full_cfg = get_config(arch)
    repeats = full_cfg.num_pattern_repeats
    mini1 = lower_cell(arch, shape, mesh, cfg=runtime_config(arch, True, 1), donate=False)
    if repeats == 1:
        out = dict(mini1)
        out["composite"] = {
            "flops": mini1["flops"],
            "bytes_accessed": mini1["bytes_accessed"],
            "collectives": mini1["collectives"],
            "repeats": 1,
        }
        return out
    mini0 = lower_cell(arch, shape, mesh, cfg=runtime_config(arch, True, 0), donate=False)

    def comp(key):
        u = (mini1[key] or 0.0) - (mini0[key] or 0.0)
        return (mini0[key] or 0.0) + max(u, 0.0) * repeats

    coll: Dict[str, float] = {}
    kinds = set(mini1["collectives"]) | set(mini0["collectives"])
    for k in kinds:
        a = mini0["collectives"].get(k, 0.0)
        b = mini1["collectives"].get(k, 0.0)
        u = b - a
        coll[k] = a + max(u, 0.0) * repeats
    return {
        "mini0": mini0,
        "mini1": mini1,
        "composite": {
            "flops": comp("flops"),
            "bytes_accessed": comp("bytes_accessed"),
            "collectives": coll,
            "repeats": repeats,
        },
    }


# ------------------------------ runner -------------------------------------


def run_cell(arch: str, shape_name: str, multi_pod: bool, with_cost: bool) -> Dict[str, Any]:
    shape = SHAPES[shape_name]
    ok, reason = cell_applicable(arch, shape_name)
    if not ok:
        return {"skipped": True, "reason": reason}
    mesh = make_production_mesh(multi_pod=multi_pod)
    rec = lower_cell(arch, shape, mesh)
    rec["devices"] = int(np.prod(list(mesh.shape.values())))
    if with_cost and not multi_pod:
        rec["cost"] = composite_cost(arch, shape, mesh)
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True, choices=list(SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--no-cost", action="store_true")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    key = f"{args.arch}__{args.shape}__{'multipod' if args.multi_pod else 'pod'}"
    out_dir = args.out or os.path.abspath(ARTIFACTS)
    os.makedirs(out_dir, exist_ok=True)
    out_path = os.path.join(out_dir, key + ".json")
    try:
        rec = run_cell(args.arch, args.shape, args.multi_pod, with_cost=not args.no_cost)
        rec["ok"] = not rec.get("skipped", False)
    except Exception as e:  # noqa: BLE001 - recorded, rerun after fix
        rec = {"ok": False, "error": f"{type(e).__name__}: {e}", "trace": traceback.format_exc()}
    rec["arch"] = args.arch
    rec["shape"] = args.shape
    rec["multi_pod"] = args.multi_pod
    with open(out_path, "w") as f:
        json.dump(rec, f, indent=2, default=float)
    status = "SKIP" if rec.get("skipped") else ("OK" if rec["ok"] else "FAIL")
    print(f"[{status}] {key}")
    if rec.get("error"):
        print(rec["error"])
    if rec.get("temp_size_in_bytes") is not None:
        print(f"  temp bytes/device: {rec['temp_size_in_bytes']:.3e}")
    if rec.get("flops") is not None:
        print(f"  scanned-HLO flops (per device): {rec['flops']:.3e}")
    if "cost" in rec:
        c = rec["cost"]["composite"]
        print(f"  composite flops (per device): {c['flops']:.3e}  collectives: { {k: f'{v:.2e}' for k, v in c['collectives'].items()} }")


if __name__ == "__main__":
    main()
