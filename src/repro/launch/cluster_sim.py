"""Cluster-day simulation: the paper's scheduler in charge of a TPU pod.

``python -m repro.launch.cluster_sim --policy dynamic --iterations 20``

Runs simulated days where diurnal (arch x shape) jobs from the assigned
architectures hit one 256-chip pod that EDF-SS schedules across the 12
partition profiles, with the repartitioning policy of your choice; energy
uses the TPU pod power curve.  ``--failures`` injects Poisson slice failures
(jobs requeue with checkpoint-gap work loss; the policy degrades to a
holed configuration until repair) — the paper's mechanism doubling as the
recovery path (DESIGN.md §5).
"""

from __future__ import annotations

import argparse
import math
from typing import Dict, List, Optional

import numpy as np

from repro.cluster.workload import ClusterWorkloadSpec, generate_cluster_jobs
from repro.core.metrics import SimResult, et_table
from repro.core.power import TPU_V5E_POD
from repro.core.schedulers import make_scheduler
from repro.core.simulator import (
    DayNightPolicy,
    MIGSimulator,
    RepartitionPolicy,
    StaticPolicy,
)
from repro.distributed.fault_tolerance import FailureModel

__all__ = ["FailureAwarePolicy", "queue_heuristic_policy", "run_days", "main"]

# pod repartition penalty: rebuild meshes + restore job state from ckpt (min)
POD_REPARTITION_MIN = 0.5


class QueueHeuristicPolicy:
    """Queue-pressure heuristic (the paper's Fig. 11 intuition distilled)."""

    initial_config = 2

    def decide(self, t, sim):
        snap = sim.snapshot()  # observable state only (engine snapshot API)
        q = snap.jobs_in_system
        tgt = 1 if q <= 1 else 2 if q <= 2 else 3 if q <= 3 else 6 if q <= 5 else 9 if q <= 7 else 12
        return tgt if tgt != snap.config_id else None

    def next_timer(self, t):
        return None


def queue_heuristic_policy() -> QueueHeuristicPolicy:
    return QueueHeuristicPolicy()


class FailureAwarePolicy:
    """Wraps a policy with slice-failure handling.

    On failure: running jobs are requeued by the forced repartition, each
    charged the checkpoint-gap work loss; the pod runs a holed configuration
    (config 5: 6/7 slots) until repair.
    """

    DEGRADED_CONFIG = 5

    def __init__(self, inner: RepartitionPolicy, failures, model: FailureModel):
        self.inner = inner
        self.initial_config = inner.initial_config
        self.events = list(failures)  # [(t_fail, slice_idx, t_repair)]
        self.outages: List = []
        self.recoveries = 0
        self.lost_work_min = 0.0

    def _outage_at(self, t: float) -> bool:
        return any(f <= t < r for f, _, r in self.events)

    def decide(self, t, sim):
        if self._outage_at(t):
            if sim.partition.config_id != self.DEGRADED_CONFIG:
                # charge checkpoint-gap loss to every running job
                for jid in list(sim.assignment):
                    job = sim.active[jid]
                    lost = min(10.0, job.work - job.remaining)
                    lost = max(lost, 0.0) * 0.5  # expected gap/2
                    job.remaining = min(job.remaining + lost, job.work)
                    self.lost_work_min += lost
                self.recoveries += 1
                return self.DEGRADED_CONFIG
            return None
        return self.inner.decide(t, sim)

    def next_timer(self, t):
        bounds = [x for f, _, r in self.events for x in (f, r) if x > t + 1e-9]
        inner = self.inner.next_timer(t)
        if inner is not None:
            bounds.append(inner)
        return min(bounds) if bounds else None


def run_days(
    policy_factory,
    iterations: int = 10,
    spec: Optional[ClusterWorkloadSpec] = None,
    scheduler: str = "EDF-SS",
    failures: Optional[FailureModel] = None,
    seed: int = 0,
) -> List[SimResult]:
    spec = spec or ClusterWorkloadSpec()
    sim = MIGSimulator(
        make_scheduler(scheduler),
        power_model=TPU_V5E_POD,
        repartition_penalty_min=POD_REPARTITION_MIN,
    )
    out: List[SimResult] = []
    for it in range(iterations):
        jobs = generate_cluster_jobs(spec, seed=seed + it)
        policy = policy_factory()
        if failures is not None:
            fl = failures.sample_failures(7, spec.horizon_min)
            policy = FailureAwarePolicy(policy, fl, failures)
        out.append(sim.run(jobs, policy=policy))
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument(
        "--policy",
        default="heuristic",
        choices=["static", "daynight", "heuristic", "dynamic"],
    )
    ap.add_argument("--iterations", type=int, default=10)
    ap.add_argument("--failures", action="store_true")
    ap.add_argument("--dqn-params", default="artifacts/dqn_params.npz")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    def factory():
        if args.policy == "static":
            return StaticPolicy(3)
        if args.policy == "daynight":
            return DayNightPolicy()
        if args.policy == "heuristic":
            return queue_heuristic_policy()
        from repro.core.rl import DQNConfig, DQNLearner, greedy_policy
        from repro.core.rl.env import FEATURE_DIM

        learner = DQNLearner(DQNConfig(state_dim=FEATURE_DIM))
        learner.load(args.dqn_params)
        return greedy_policy(learner)

    fm = FailureModel(mtbf_minutes=2 * 24 * 60.0) if args.failures else None
    results = run_days(factory, iterations=args.iterations, failures=fm, seed=args.seed)
    n = len(results)
    print(
        f"policy={args.policy} days={n} "
        f"energy={sum(r.energy_wh for r in results)/n/1000.0:.1f} kWh/day "
        f"avg_tardiness={sum(r.avg_tardiness for r in results)/n:.3f} min "
        f"repartitions={sum(r.repartitions for r in results)/n:.1f}/day "
        f"misses={sum(r.deadline_misses for r in results)/n:.1f}/day"
    )


if __name__ == "__main__":
    main()
