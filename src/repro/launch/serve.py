"""Serving driver: batched prefill+decode on whatever devices exist.

``python -m repro.launch.serve --arch mixtral-8x7b --smoke`` serves the
reduced config on CPU; on a TPU pod the full config + production mesh apply
(decode cells of the dry-run lower exactly this step).
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, smoke_config
from repro.distributed.sharding import cache_shardings, param_shardings
from repro.launch.mesh import make_production_mesh, make_smoke_mesh, set_ambient_mesh
from repro.models import decode_step, init_cache, init_params


def serve(
    arch: str,
    smoke: bool = True,
    batch: int = 4,
    steps: int = 32,
    max_len: int = 128,
    production_mesh: bool = False,
    seed: int = 0,
    verbose: bool = True,
) -> float:
    cfg = smoke_config(arch) if smoke else get_config(arch)
    mesh = make_production_mesh() if production_mesh else make_smoke_mesh()
    set_ambient_mesh(mesh)

    params = init_params(cfg, seed=seed)
    params = jax.device_put(params, param_shardings(params, mesh))
    cache = init_cache(cfg, batch, max_len)
    cache = jax.device_put(cache, cache_shardings(cache, mesh, batch))

    step = jax.jit(
        lambda p, c, t, i: decode_step(cfg, p, c, t, i, impl="ref"),
        donate_argnums=(1,),
    )
    rng = np.random.default_rng(seed)
    tok = jnp.asarray(rng.integers(0, cfg.vocab_size, (batch, 1)), jnp.int32)
    with mesh:
        logits, cache = step(params, cache, tok, jnp.asarray(0, jnp.int32))  # compile
        t0 = time.time()
        for i in range(1, steps):
            tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
            logits, cache = step(params, cache, tok, jnp.asarray(i, jnp.int32))
        jax.block_until_ready(logits)
    dt = time.time() - t0
    tps = batch * (steps - 1) / dt
    if verbose:
        print(f"{arch}: {tps:.1f} tok/s (batch={batch}, {dt/(steps-1)*1e3:.1f} ms/step)")
    return tps


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--full", dest="smoke", action="store_false")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--steps", type=int, default=32)
    ap.add_argument("--production-mesh", action="store_true")
    args = ap.parse_args()
    serve(
        args.arch,
        smoke=args.smoke,
        batch=args.batch,
        steps=args.steps,
        production_mesh=args.production_mesh,
    )


if __name__ == "__main__":
    main()
