"""Production mesh construction.

Defined as FUNCTIONS (never module-level constants) so importing this module
does not touch jax device state — the dry-run must set
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before jax init,
and smoke tests must keep seeing 1 device.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh

__all__ = ["make_production_mesh", "make_smoke_mesh", "set_ambient_mesh", "POD_SHAPE"]

POD_SHAPE = (16, 16)  # one v5e pod: 256 chips


def _axis_types_kwargs(n_axes: int) -> dict:
    # jax >= 0.5 grows Mesh(axis_types=...); Auto is that API's default and the
    # only behavior older jax has, so on old jax we simply omit the argument.
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return {}
    return {"axis_types": (axis_type.Auto,) * n_axes}


def set_ambient_mesh(mesh: Mesh) -> None:
    """Populate the ambient/abstract mesh (feeds ``repro.distributed.hints``).

    ``jax.sharding.set_mesh`` only exists on jax >= 0.5; on older jax the
    hints layer already degrades to a no-op, and all real placement goes
    through explicit ``device_put`` shardings + ``with mesh:`` contexts, so
    skipping the call preserves behavior.
    """
    set_fn = getattr(jax.sharding, "set_mesh", None)
    if set_fn is not None:
        set_fn(mesh)


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    """The assignment's production mesh: 16x16 per pod, 2 pods multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, **_axis_types_kwargs(len(axes)))


def make_smoke_mesh(
    data: Optional[int] = None, model: Optional[int] = None
) -> Mesh:
    """Small mesh over whatever devices exist (tests / examples)."""
    n = jax.device_count()
    if data is None or model is None:
        model = 1
        data = n
        while data % 2 == 0 and model < data:
            data //= 2
            model *= 2
    assert data * model <= n, (data, model, n)
    devs = np.asarray(jax.devices()[: data * model]).reshape(data, model)
    return Mesh(devs, ("data", "model"), **_axis_types_kwargs(2))
