"""Training driver: end-to-end on whatever devices exist.

``python -m repro.launch.train --arch gemma3-1b --smoke --steps 200`` trains
the reduced config on CPU; on a TPU pod the full config + production mesh
apply.  Features exercised here: deterministic restart-safe data, pjit'd
train step, async checkpointing + elastic resume, loss logging.
"""

from __future__ import annotations

import argparse
import dataclasses
import os
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager, latest_step, restore_checkpoint
from repro.configs import get_config, smoke_config
from repro.data.pipeline import SyntheticLM
from repro.distributed.sharding import batch_shardings, param_shardings
from repro.distributed.step import make_train_step
from repro.launch.mesh import make_production_mesh, make_smoke_mesh, set_ambient_mesh
from repro.models import init_params
from repro.optim import AdamW, AdamWConfig, linear_warmup_cosine

__all__ = ["train", "main"]


def train(
    arch: str,
    steps: int = 100,
    smoke: bool = True,
    global_batch: int = 8,
    seq_len: int = 256,
    accum_steps: int = 1,
    lr: float = 3e-4,
    ckpt_dir: Optional[str] = None,
    ckpt_every: int = 50,
    seed: int = 0,
    production_mesh: bool = False,
    log_every: int = 10,
    verbose: bool = True,
):
    cfg = smoke_config(arch) if smoke else get_config(arch)
    cfg = dataclasses.replace(cfg, scan_layers=True, remat="block")
    mesh = (
        make_production_mesh() if production_mesh else make_smoke_mesh()
    )
    set_ambient_mesh(mesh)

    opt = AdamW(
        AdamWConfig(lr=linear_warmup_cosine(lr, max(steps // 20, 1), steps))
    )
    step_fn = make_train_step(cfg, opt, accum_steps=accum_steps, impl="ref")

    params = init_params(cfg, seed=seed)
    opt_state = opt.init(params)
    p_shard = param_shardings(params, mesh)
    params = jax.device_put(params, p_shard)

    data = SyntheticLM(cfg, global_batch, seq_len, seed=seed)
    start_step = 0
    manager = None
    if ckpt_dir:
        manager = CheckpointManager(ckpt_dir)
        last = latest_step(ckpt_dir)
        if last is not None:
            state = restore_checkpoint(
                ckpt_dir, last, jax.eval_shape(lambda: {"params": params, "opt": opt_state})
            )
            params, opt_state = state["params"], state["opt"]
            start_step = last
            if verbose:
                print(f"resumed from step {last}")

    jitted = jax.jit(step_fn, donate_argnums=(0, 1))
    losses = []
    t0 = time.time()
    with mesh:
        for step in range(start_step, steps):
            batch = jax.device_put(
                data.batch_for_step(step), batch_shardings(
                    jax.tree_util.tree_map(np.asarray, data.batch_for_step(step)), mesh
                )
            )
            params, opt_state, metrics = jitted(params, opt_state, batch)
            losses.append(float(metrics["loss"]))
            if verbose and (step + 1) % log_every == 0:
                dt = (time.time() - t0) / max(step + 1 - start_step, 1)
                print(
                    f"step {step + 1}/{steps} loss={losses[-1]:.4f} "
                    f"gnorm={float(metrics['grad_norm']):.3f} ({dt * 1e3:.0f} ms/step)"
                )
            if manager and (step + 1) % ckpt_every == 0:
                manager.save_async(step + 1, {"params": params, "opt": opt_state})
    if manager:
        manager.wait()
    return params, losses


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--full", dest="smoke", action="store_false")
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=256)
    ap.add_argument("--accum-steps", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--production-mesh", action="store_true")
    args = ap.parse_args()
    _, losses = train(
        args.arch,
        steps=args.steps,
        smoke=args.smoke,
        global_batch=args.global_batch,
        seq_len=args.seq_len,
        accum_steps=args.accum_steps,
        lr=args.lr,
        ckpt_dir=args.ckpt_dir,
        production_mesh=args.production_mesh,
    )
    n = max(len(losses) // 10, 1)
    print(f"first-{n} loss {np.mean(losses[:n]):.4f} -> last-{n} {np.mean(losses[-n:]):.4f}")


if __name__ == "__main__":
    main()
