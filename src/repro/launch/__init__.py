"""Launchers: production mesh, multi-pod dry-run, train/serve drivers,
and the cluster-day simulation that puts the paper's scheduler in charge
of the pod."""
