"""Assigned input shapes x applicability, and per-cell launch parameters.

LM transformer shapes (assignment brief):
  train_4k     seq 4,096   global_batch 256   (training)      -> train_step
  prefill_32k  seq 32,768  global_batch 32    (prefill)       -> prefill_step
  decode_32k   seq 32,768  global_batch 128   (decode)        -> serve_step
  long_500k    seq 524,288 global_batch 1     (long decode)   -> serve_step

``long_500k`` requires sub-quadratic attention — skipped for pure
full-attention archs (DESIGN.md §4), run for SSM / hybrid / SWA / 5:1-local
archs.  Gradient-accumulation steps are sized so the per-device microbatch
stays ~1 row on the data axis for the largest models (saved-residual memory
scales with the microbatch under layer-scan remat).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

from repro.configs import get_config
from repro.models.config import ArchConfig

__all__ = ["ShapeSpec", "SHAPES", "cell_applicable", "accum_steps_for", "all_cells"]


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str  # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", "train", 4_096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32_768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524_288, 1),
}

# archs allowed to run long_500k (sub-quadratic decode; DESIGN.md §4)
LONG_CAPABLE = {
    "xlstm-350m",       # recurrent O(1) state
    "jamba-v0.1-52b",   # mamba-dominant hybrid
    "mixtral-8x7b",     # sliding-window attention (ring-buffer KV)
    "gemma3-12b",       # 5:1 local:global
    "gemma3-1b",        # 5:1 local:global
}

SKIP_REASONS = {
    ("nemotron-4-340b", "long_500k"): "pure full attention (quadratic prefill, O(seq) full-KV decode)",
    ("stablelm-3b", "long_500k"): "pure full attention",
    ("granite-moe-3b-a800m", "long_500k"): "pure full attention",
    ("phi-3-vision-4.2b", "long_500k"): "pure full attention (phi3-mini backbone)",
    ("whisper-base", "long_500k"): "enc-dec; decoder context is 448 tokens by construction",
}


def cell_applicable(arch: str, shape: str) -> Tuple[bool, str]:
    if shape == "long_500k" and arch not in LONG_CAPABLE:
        return False, SKIP_REASONS.get((arch, shape), "full attention")
    return True, ""


def accum_steps_for(arch: str, shape: ShapeSpec, data_parallel: int) -> int:
    """Gradient-accumulation steps for train cells (memory-driven)."""
    if shape.kind != "train":
        return 1
    cfg = get_config(arch)
    # target microbatch rows per data shard: 1 for giant models, more for small
    if cfg.d_model >= 8_000:
        per_shard = 1
    elif cfg.d_model >= 2_500:
        per_shard = 2
    else:
        per_shard = 8
    micro_global = max(per_shard * data_parallel, 1)
    accum = max(shape.global_batch // micro_global, 1)
    while shape.global_batch % (accum) != 0 or (shape.global_batch // accum) % data_parallel != 0:
        accum -= 1
    return max(accum, 1)


def all_cells():
    from repro.configs import ARCH_IDS, ALIASES

    inv = {v: k for k, v in ALIASES.items()}
    for arch_mod in ARCH_IDS:
        arch = inv[arch_mod]
        for shape in SHAPES.values():
            yield arch, shape
