"""Arrival-rate forecasting: fitted diurnal Fourier day-model + EWMA bias.

The paper's workload (§V-A, Fig. 5) is a non-homogeneous Poisson process
whose rate repeats daily.  A predictive repartitioning controller needs
λ̂(t+h) for lookahead horizons h of one to a few hours; we factor that into

* a **day model** — a truncated Fourier series over the 24 h period fitted
  by least squares to binned arrival counts from training days (any
  registered :mod:`repro.core.scenarios` entry), capturing the recurring
  diurnal shape, and
* an **EWMA bias tracker** — an online multiplicative correction
  ``observed / predicted`` over trailing windows of the *current* day, so a
  hotter- or quieter-than-usual day shifts every forecast up or down without
  refitting.

Both parts are deterministic: the fit is a least-squares solve on
deterministic scenario streams, and the tracker's state is a pure function
of the observed arrival count sequence.  ``tests/test_forecast.py`` pins
fit accuracy against the Fig. 5 ground truth and per-seed determinism.
"""

from __future__ import annotations

import dataclasses
import functools
import math
from typing import Optional, Sequence, Tuple

import numpy as np

__all__ = [
    "FourierDayModel",
    "fit_fourier_day_model",
    "fit_scenario_forecaster",
    "EWMABiasTracker",
    "ArrivalForecaster",
]

MINUTES_PER_DAY = 24 * 60.0


@dataclasses.dataclass(frozen=True)
class FourierDayModel:
    """Diurnal rate model: truncated Fourier series over a 24 h period.

    ``rate(t) = max(c0 + Σ_k a_k cos(2πkt/T) + b_k sin(2πkt/T), floor)``
    with ``t`` in absolute minutes (the day phase is ``t mod T``).  Floors at
    ``min_rate`` because a thinning sampler / fluid model needs λ ≥ 0.
    """

    mean: float  # c0, jobs/min
    cos_coeffs: Tuple[float, ...]  # a_1..a_K
    sin_coeffs: Tuple[float, ...]  # b_1..b_K
    period_min: float = MINUTES_PER_DAY
    min_rate: float = 0.0

    @property
    def harmonics(self) -> int:
        return len(self.cos_coeffs)

    def rate(self, t_min: float) -> float:
        """Forecast arrival rate (jobs/min) at absolute time ``t_min``."""
        w = 2.0 * math.pi * (t_min % self.period_min) / self.period_min
        r = self.mean
        for k in range(self.harmonics):
            r += self.cos_coeffs[k] * math.cos((k + 1) * w)
            r += self.sin_coeffs[k] * math.sin((k + 1) * w)
        return max(r, self.min_rate)

    def mean_rate(self, t0: float, t1: float, steps: int = 8) -> float:
        """Average forecast rate over [t0, t1] (midpoint rule)."""
        if t1 <= t0:
            return self.rate(t0)
        dt = (t1 - t0) / steps
        return sum(self.rate(t0 + (i + 0.5) * dt) for i in range(steps)) / steps


def fit_fourier_day_model(
    arrival_times: Sequence[float],
    total_minutes: float,
    harmonics: int = 3,
    bin_min: float = 15.0,
    min_rate: float = 0.0,
    num_streams: int = 1,
) -> FourierDayModel:
    """Least-squares Fourier fit to arrivals folded onto one day.

    ``arrival_times`` holds the pooled arrivals of ``num_streams``
    independent observation spans, each covering ``[0, total_minutes)``
    (a single span may run several days); counts are folded onto
    day-of-period bins, converted to an empirical rate (jobs/min) per bin
    using the per-bin observation coverage, and fit with ``harmonics``
    Fourier pairs.  Keeping the per-stream span explicit matters for
    sub-day horizons: eight 4-hour streams cover the same four hours eight
    times — not 32 hours wrapped around the clock.  Deterministic: a dense
    least-squares solve, no RNG.
    """
    if total_minutes <= 0.0:
        raise ValueError("total_minutes must be positive")
    if num_streams < 1:
        raise ValueError("num_streams must be >= 1")
    n_bins = max(int(round(MINUTES_PER_DAY / bin_min)), 1)
    width = MINUTES_PER_DAY / n_bins
    counts = np.zeros(n_bins)
    for t in arrival_times:
        counts[int((t % MINUTES_PER_DAY) / width) % n_bins] += 1.0
    # minutes of observation covering each day-bin: one span's coverage
    # (handles partial days), replicated across the identical-phase streams
    coverage = np.zeros(n_bins)
    full_days, rem = divmod(total_minutes, MINUTES_PER_DAY)
    coverage += full_days * width
    for b in range(n_bins):
        lo = b * width
        coverage[b] += min(max(rem - lo, 0.0), width)
    coverage *= num_streams
    observed = coverage > 1e-9
    rates = counts[observed] / coverage[observed]
    centers = (np.arange(n_bins)[observed] + 0.5) * width
    w = 2.0 * np.pi * centers / MINUTES_PER_DAY
    cols = [np.ones_like(w)]
    for k in range(1, harmonics + 1):
        cols.append(np.cos(k * w))
        cols.append(np.sin(k * w))
    design = np.stack(cols, axis=1)
    coeffs, *_ = np.linalg.lstsq(design, rates, rcond=None)
    return FourierDayModel(
        mean=float(coeffs[0]),
        cos_coeffs=tuple(float(c) for c in coeffs[1::2]),
        sin_coeffs=tuple(float(c) for c in coeffs[2::2]),
        min_rate=min_rate,
    )


@functools.lru_cache(maxsize=32)
def fit_scenario_forecaster(
    scenario: str = "paper-diurnal",
    train_seeds: int = 8,
    harmonics: int = 3,
    bin_min: float = 15.0,
    scenario_kwargs: Tuple[Tuple[str, object], ...] = (),
) -> FourierDayModel:
    """Fit a day model on ``train_seeds`` days of a registered scenario.

    Each seed generates one independent scenario stream; arrivals from all
    of them are folded into the day-bin fit, so the model sees the *mean*
    diurnal shape rather than one day's Poisson noise.  Cached per argument
    tuple — sweep workers fitting the same model pay the generation cost
    once per process.  ``scenario_kwargs`` is a sorted tuple of pairs (not a
    dict) so the cache key is hashable; :func:`ForecastPolicy` callers
    normally go through :func:`repro.forecast.policy.ForecastPolicy`'s
    factory which handles the conversion.
    """
    from repro.core.scenarios import generate_scenario, resolve_scenario_kwargs

    kwargs = dict(scenario_kwargs)
    resolved = resolve_scenario_kwargs(scenario, kwargs)
    horizon = float(resolved.get("horizon_min", MINUTES_PER_DAY))
    arrivals: list = []
    for seed in range(train_seeds):
        arrivals.extend(j.arrival for j in generate_scenario(scenario, seed=seed, **kwargs))
    return fit_fourier_day_model(
        arrivals,
        total_minutes=horizon,
        harmonics=harmonics,
        bin_min=bin_min,
        num_streams=train_seeds,
    )


@dataclasses.dataclass
class EWMABiasTracker:
    """Online multiplicative bias over a day model: EWMA of observed/expected.

    At each update the tracker is handed the cumulative arrival count; it
    closes trailing windows of ``window_min`` minutes, computes the ratio of
    observed arrivals to the day model's expectation for that window, and
    folds it into an exponentially weighted level.  ``bias`` multiplies
    every forecast, clipped to ``[clip_lo, clip_hi]`` so a silent night
    cannot zero out (or a burst blow up) the whole lookahead.

    Deterministic given the (t, cumulative-count) observation sequence.
    """

    alpha: float = 0.15
    window_min: float = 30.0
    clip_lo: float = 0.6
    clip_hi: float = 2.5
    level: float = 1.0
    _window_start: float = 0.0
    _window_base_count: int = 0

    def update(self, model: FourierDayModel, t: float, cumulative_count: int) -> None:
        """Fold any completed observation windows up to time ``t``."""
        if t < self._window_start:  # new episode reusing the policy object
            self.reset()
        while t - self._window_start >= self.window_min:
            w0 = self._window_start
            w1 = w0 + self.window_min
            expected = model.mean_rate(w0, w1) * self.window_min
            # attribute the cumulative count seen *now* to the closed window;
            # windows close in order so each arrival is counted exactly once
            observed = cumulative_count - self._window_base_count
            if expected > 1e-9:
                ratio = observed / expected
                self.level += self.alpha * (ratio - self.level)
            self._window_start = w1
            self._window_base_count = cumulative_count

    @property
    def bias(self) -> float:
        return min(max(self.level, self.clip_lo), self.clip_hi)

    def reset(self) -> None:
        self.level = 1.0
        self._window_start = 0.0
        self._window_base_count = 0


class ArrivalForecaster:
    """Day model + online bias: the rate source a :class:`ForecastPolicy` reads.

    ``observe(t, cumulative_count)`` is called by the policy at decision
    events with the total number of arrivals the simulator has seen so far;
    ``rate(t)`` then returns the bias-corrected forecast.  A fresh tracker
    is installed by :meth:`reset` (per simulated day/episode).
    """

    def __init__(
        self,
        model: FourierDayModel,
        tracker: Optional[EWMABiasTracker] = None,
    ) -> None:
        self.model = model
        self.tracker = tracker if tracker is not None else EWMABiasTracker()

    def observe(self, t: float, cumulative_count: int) -> None:
        self.tracker.update(self.model, t, cumulative_count)

    def rate(self, t: float) -> float:
        return self.model.rate(t) * self.tracker.bias

    def reset(self) -> None:
        self.tracker.reset()
