"""ForecastPolicy: model-predictive repartitioning over a fluid queue model.

At each decision event (arrival/completion/periodic timer) the policy

1. updates its arrival forecaster with the arrivals realized so far,
2. reads the simulator's *actual* state — jobs in system and outstanding
   work in 1g-minutes (both are observable by a real MIG controller),
3. for every candidate configuration rolls a cheap fluid/queueing
   approximation of the simulator forward over ``horizon_min`` minutes:
   forecast arrivals feed a two-class (inference/training) backlog, seated
   slices drain it at the §V-A job-mix expected throughput with
   duty-cycle-correct energy, an Erlang-C term supplies the stochastic
   queueing wait a deterministic fluid cannot see, and arrivals are charged
   the expected lateness read off a per-config curve precomputed from a
   deterministic sample of the §V-A job distribution (which is what prices
   the *tail*: a linear training job with a tight deadline needs the 4g
   slice that some layouts simply do not have),
4. charges switching candidates the §IV-D-3 repartition penalty (a blocked
   GPU for 4 s) inside the rollout,
5. picks the configuration minimizing the predicted ET scalarization
   ``(a·E + T̄)/(a + 1)`` — switching only when the predicted improvement
   clears ``switch_margin`` (``downsize_margin`` when cutting parallelism:
   shrinking on a transient quiet dip is how a controller gets caught by
   the next burst) and the configuration has dwelt ``min_dwell_min``, so
   the repartition penalty always amortizes (pinned by
   ``tests/test_forecast.py``).

The fluid model is the same first-order backlog estimate the fleet
dispatcher uses for placement scoring (:mod:`repro.fleet.dispatch`) —
deliberately far cheaper than the event simulator it approximates, because
it runs |configs| × (horizon/step) times per decision.
"""

from __future__ import annotations

import bisect
import functools
import math
from typing import Dict, List, Mapping, Optional, Tuple

import numpy as np

from repro.core.jobs import SUBLINEAR_CURVES, Elasticity, LINEAR, capped
from repro.core.power import A100_250W, PowerModel
from repro.core.simulator import (
    REPARTITION_MODES,
    REPARTITION_PENALTY_MIN,
    MIGSimulator,
)
from repro.core.slices import MIG_CONFIGS, Partition, transition

__all__ = [
    "expected_throughput",
    "EFFECTIVE_THROUGHPUT",
    "erlang_c_wait",
    "DEFAULT_CANDIDATES",
    "ForecastPolicy",
    "device_forecast_factory",
]


def expected_throughput(slots: int) -> float:
    """E[throughput] of a §V-A random job on a slice of ``slots`` compute.

    The workload draws its elasticity uniformly over {linear, capped,
    sublinear} with capped caps uniform on {2, 3, 4} and the four sublinear
    curves equally likely — the expectation simply averages those profiles.
    """
    linear = float(slots)
    capped_mean = sum(capped(c).throughput(slots) for c in (2, 3, 4)) / 3.0
    sub_mean = sum(e.throughput(slots) for e in SUBLINEAR_CURVES.values()) / len(
        SUBLINEAR_CURVES
    )
    return (linear + capped_mean + sub_mean) / 3.0


#: memoized E[tp] per canonical slice size (1, 2, 3, 4, 7)
EFFECTIVE_THROUGHPUT: Dict[int, float] = {k: expected_throughput(k) for k in (1, 2, 3, 4, 7)}


def erlang_c_wait(servers: int, lam: float, mu_per_server: float) -> float:
    """Expected M/M/c queueing wait (minutes) — the stochastic term a
    deterministic fluid model cannot see.

    At identical utilization a 2-slice configuration queues jobs far longer
    than a 4-slice one; this is what differentiates parallelism levels on
    the daytime plateau, so the lookahead must price it.  Uses the Erlang-B
    recursion (c ≤ 7, a handful of multiplies); returns 0 for an idle
    system and ``inf`` for an overloaded one (the caller caps it).
    """
    if lam <= 1e-12 or servers <= 0:
        return 0.0
    cap = servers * mu_per_server
    if lam >= cap * 0.999:
        return math.inf
    a = lam / mu_per_server
    b = 1.0
    for k in range(1, servers + 1):
        b = a * b / (k + a * b)
    rho = lam / cap
    p_wait = b / (1.0 - rho * (1.0 - b))
    return p_wait / (cap - lam)


# §V-A job-mix constants the two-class fluid model runs on, sourced from
# the workload defaults so a tuned WorkloadSpec default cannot silently
# diverge from the controller's priors.  Inference is 80 % of arrivals
# with Exp(mean 3) work; training is 20 % with U(10, 40) (mean 25) — a
# fifth of the jobs but two thirds of the work.
from repro.core.workload import WorkloadSpec as _WorkloadSpec

_SPEC_DEFAULTS = _WorkloadSpec()
_INFERENCE_SPLIT = _SPEC_DEFAULTS.inference_split
_MEAN_WORK_INF = _SPEC_DEFAULTS.inference_mean_min
_MEAN_WORK_TRN = (_SPEC_DEFAULTS.training_lo_min + _SPEC_DEFAULTS.training_hi_min) / 2.0

#: Default candidate configurations for the paper's A100 table: the coarse
#: family the controller modulates between — full GPU overnight
#: (race-to-idle), the 4g+3g split on the shoulders, and the paper's
#: workhorse 4g+2g+1g layout through the daytime plateau.  Matches the
#: preferred-configuration structure of Fig. 11, and EXPERIMENTS.md
#: §Predictive-controller measures this pruning beating both the full
#: 12-config search (whose fine layouts the fluid model over-rates) and
#: every static baseline on ET.  Pass ``configs=`` to search a different
#: set (e.g. the device's full table).
DEFAULT_CANDIDATES = (1, 2, 3)


@functools.lru_cache(maxsize=4)
def _job_samples(n: int = 512) -> Tuple[Tuple[str, float, float, Elasticity], ...]:
    """A fixed, deterministic sample of the §V-A job distribution.

    Each entry is ``(kind, work, deadline_slack, elasticity)`` with the
    slack already resolved to minutes (``u * work / tp_el(7)``,
    u ~ U(1.2, 4.0)).  Drawn once from a pinned seed so every
    :class:`ForecastPolicy` instance — in any process — prices lateness
    against the identical sample (sweep determinism depends on it).
    """
    rng = np.random.default_rng(20250801)
    curves = list(SUBLINEAR_CURVES.values())
    out: List[Tuple[str, float, float, Elasticity]] = []
    for _ in range(n):
        is_inf = rng.uniform() < _INFERENCE_SPLIT
        work = (
            max(rng.exponential(_MEAN_WORK_INF), 1.0 / 60.0)
            if is_inf
            else rng.uniform(_SPEC_DEFAULTS.training_lo_min, _SPEC_DEFAULTS.training_hi_min)
        )
        u = rng.integers(0, 3)
        if u == 0:
            elast = LINEAR
        elif u == 1:
            elast = capped(int(rng.choice([2, 3, 4])))
        else:
            elast = curves[int(rng.integers(0, len(curves)))]
        slack = (
            rng.uniform(_SPEC_DEFAULTS.slack_lo, _SPEC_DEFAULTS.slack_hi)
            * elast.duration(work, 7)
        )
        out.append(("inf" if is_inf else "trn", float(work), float(slack), elast))
    return tuple(out)


def _config_tables(
    partition: Partition,
) -> Tuple[Tuple[float, ...], Tuple[float, ...], float, float]:
    """Per-config lateness curve + service moments from the job sample.

    For each sampled job, EDF-SS-style smallest-sufficient placement picks
    its slice on this partition (the slowest service that still meets the
    deadline at zero wait, else the fastest available); the job's
    *headroom* ``h = slack - service`` is how much queueing wait it
    tolerates before going late.  Expected lateness per arrival is then
    ``late(wait) = mean_j max(wait - h_j, 0)`` — piecewise linear, returned
    as (sorted headrooms, prefix sums) for O(log n) evaluation.  Jobs with
    negative headroom are late even on an idle GPU: exactly the tail a
    mean-job model misses on layouts lacking a big slice.

    Also returns the first two moments of the *service-time* distribution
    this placement induces — ``(mu_per_server, mg_factor)`` — feeding an
    M/G/c-corrected Erlang wait: the §V-A mix is heavy-tailed (a training
    job holds a server for minutes while sub-minute inference queues), and
    an M/M/c wait on the mean service underestimates that by the classic
    ``(1 + CV²)/2`` factor.
    """
    sizes = sorted(set(partition.slot_sizes()))
    headrooms: List[float] = []
    s1 = s2 = 0.0
    for _, work, slack, elast in _job_samples():
        candidates = [work / elast.throughput(s) for s in sizes]
        sufficient = [d for d in candidates if d <= slack + 1e-12]
        # smallest sufficient slice = the slowest service that still meets
        # the deadline; an impossible deadline falls back to the fastest
        service = max(sufficient) if sufficient else min(candidates)
        headrooms.append(slack - service)
        s1 += service
        s2 += service * service
    n = len(headrooms)
    mean_s = s1 / n
    cv2 = max(s2 / n / (mean_s * mean_s) - 1.0, 0.0)
    headrooms.sort()
    prefix = [0.0]
    for h in headrooms:
        prefix.append(prefix[-1] + h)
    return tuple(headrooms), tuple(prefix), 1.0 / mean_s, (1.0 + cv2) / 2.0


class ForecastPolicy:
    """Predictive repartitioning controller (forecast + MPC lookahead).

    Parameters
    ----------
    forecaster:
        An object with ``rate(t) -> jobs/min`` (and optionally
        ``observe(t, cumulative_count)`` / ``reset()``), normally an
        :class:`~repro.forecast.forecaster.ArrivalForecaster`.  ``None``
        fits the default paper-diurnal day model (cached per process).
    configs / power:
        The device's partition table and power curve — defaults to the
        paper's A100.  Passing a different device's pair makes the
        controller native to that device (fleet heterogeneity); on the
        registry path a non-A100 device instead gets the A100-space choices
        translated by :class:`repro.fleet.DeviceAdaptedPolicy`.
    horizon_min / step_min:
        Lookahead length and fluid integration step.
    et_alpha:
        Energy weight ``a`` of the predicted-ET scalarization
        ``(a·E + T̄)/(a+1)`` (same form as :mod:`repro.core.metrics`).
    switch_margin / downsize_margin:
        Relative predicted-ET improvement a challenger must clear before
        the controller repartitions; cutting parallelism requires the
        larger ``downsize_margin`` (asymmetric hysteresis: shrinking on a
        transient quiet dip is how a controller gets caught by a burst).
    min_dwell_min:
        Minimum minutes between repartitions.
    eval_interval_min:
        Full candidate evaluations are throttled to at most one per this
        many minutes — except when the queue depth jumped by ≥ 2 since the
        last evaluation (a burst must be seen immediately).
    reconsider_min:
        Period of the policy's own timer, so quiet stretches without
        arrivals still get decision points (e.g. the evening ramp-down).
    max_defer_min:
        Opportunistic-switch window (partial mode only): a wanted switch
        that would displace jobs running on to-be-destroyed slices is
        deferred — decision points recur at every completion, so within a
        couple of minutes the affected instances usually drain and the
        reconfiguration lands displacement-free, exactly how
        MIG-Serving-style schedulers time reconfigurations around running
        services.  After ``max_defer_min`` minutes the switch proceeds
        anyway (the lookahead's improvement must not rot while the GPU
        waits for a long training job).
    repartition_mode:
        How the simulator this policy controls charges a reconfiguration —
        must match the simulator's own mode so the lookahead prices what
        the physics will charge.  ``"partial"`` (default): a switching
        candidate keeps the transition's *surviving* slot capacity serving
        through the 4 s stall and only the displaced share of in-flight
        work pays the upfront requeue wait; ``"drain"``: the legacy flat
        full-drain penalty (zero service during the stall, everything
        displaced).
    """

    def __init__(
        self,
        forecaster=None,
        configs: Optional[Mapping[int, Partition]] = None,
        power: PowerModel = A100_250W,
        horizon_min: float = 30.0,
        step_min: float = 3.0,
        et_alpha: float = 2e-5,
        switch_margin: float = 0.01,
        downsize_margin: float = 0.05,
        min_dwell_min: float = 1.0,
        eval_interval_min: float = 0.5,
        reconsider_min: float = 5.0,
        inference_split: float = _INFERENCE_SPLIT,
        mean_work_inf: float = _MEAN_WORK_INF,
        mean_work_trn: float = _MEAN_WORK_TRN,
        repartition_penalty_min: float = REPARTITION_PENALTY_MIN,
        repartition_mode: str = "partial",
        max_defer_min: float = 3.0,
    ) -> None:
        if repartition_mode not in REPARTITION_MODES:
            raise ValueError(
                f"unknown repartition_mode {repartition_mode!r}; "
                f"valid: {REPARTITION_MODES}"
            )
        if forecaster is None:
            from repro.forecast.forecaster import ArrivalForecaster, fit_scenario_forecaster

            forecaster = ArrivalForecaster(fit_scenario_forecaster())
        self.forecaster = forecaster
        if configs is None:
            configs = {cid: MIG_CONFIGS[cid] for cid in DEFAULT_CANDIDATES}
        self.configs: Dict[int, Partition] = dict(configs)
        self.power = power
        self.horizon_min = horizon_min
        self.step_min = step_min
        self.et_alpha = et_alpha
        self.switch_margin = switch_margin
        self.downsize_margin = downsize_margin
        self.min_dwell_min = min_dwell_min
        self.eval_interval_min = eval_interval_min
        self.reconsider_min = reconsider_min
        self.inference_split = inference_split
        self.mean_work_inf = mean_work_inf
        self.mean_work_trn = mean_work_trn
        self.penalty_min = repartition_penalty_min
        self.repartition_mode = repartition_mode
        self.max_defer_min = max_defer_min
        # memoized surviving-capacity fraction per (from, to) candidate pair
        self._surv_frac_cache: Dict[Tuple[int, int], float] = {}
        # opportunistic-switch deferral state: (wanted config, since when)
        self._defer_target: Optional[int] = None
        self._defer_since: float = 0.0

        # per-config seating order, mirroring EDF-SS's smallest-sufficient
        # placement: >=2g slices ascending (the smallest slice that meets a
        # mean job's deadline), then 1g slices — those only earn their power
        # draw once the queue is deeper than the sufficient slices
        self._seat_slots: Dict[int, Tuple[int, ...]] = {
            cid: tuple(sorted(p.slot_sizes(), key=lambda s: (s < 2, s)))
            for cid, p in self.configs.items()
        }
        # _srv[cid][k] = pooled service rate (1g-work/min) with k seats
        # busy; _pwr[cid][k] = power draw (W).  The rollout keeps the mean
        # number-in-system continuous and interpolates *between occupancy
        # levels* — E[P] = (1-frac)·P(k) + frac·P(k+1) — the
        # duty-cycle-correct expectation for a concave power curve: a
        # coarse config that races through its queue and idles must score
        # the idle watts it actually earns.
        self._srv: Dict[int, Tuple[float, ...]] = {}
        self._pwr: Dict[int, Tuple[float, ...]] = {}
        for cid, slots in self._seat_slots.items():
            eff = tuple(EFFECTIVE_THROUGHPUT[s] for s in slots)
            srv_k = [0.0]
            pwr_k = [power.power_watts(0.0)]
            for k in range(1, len(slots) + 1):
                srv_k.append(srv_k[-1] + eff[k - 1])
                pwr_k.append(power.power_watts(float(sum(slots[:k]))))
            self._srv[cid] = tuple(srv_k)
            self._pwr[cid] = tuple(pwr_k)
        # expected-lateness curves + M/G/c service moments from the
        # pinned §V-A job sample
        self._late: Dict[int, Tuple[Tuple[float, ...], Tuple[float, ...]]] = {}
        self._mu_server: Dict[int, float] = {}
        self._mg_factor: Dict[int, float] = {}
        for cid, p in self.configs.items():
            heads, prefix, mu_server, mg = _config_tables(p)
            self._late[cid] = (heads, prefix)
            self._mu_server[cid] = mu_server
            self._mg_factor[cid] = mg

        # reference drain capacity for the adaptive horizon: the best
        # pooled service rate any candidate offers on THIS device's table
        self._ref_capacity = max(srv[-1] for srv in self._srv.values())

        self._last_eval_t = -math.inf
        self._last_eval_n = 0.0
        self._last_switch_t = -math.inf
        # MPC from minute zero: the initial configuration is the lookahead
        # winner for an empty system at t=0 (no dwell/margin applies yet)
        self.initial_config = self._best_config(
            t=0.0, n_inf=0.0, w_inf=0.0, n_trn=0.0, w_trn=0.0, current=None
        )[0]

    # ------------------------------------------------------------------
    # RepartitionPolicy protocol

    def decide(self, t: float, sim: "MIGSimulator") -> Optional[int]:
        if t < self._last_eval_t - 1e-9:
            # time went backwards: the policy object is being reused for a
            # fresh episode (train_dqn guide runs) — start clean
            self.reset()
        # everything the controller reads comes through the structured
        # engine snapshot — the same observable surface a real MIG
        # controller (and the fleet dispatchers) would have
        snap = sim.snapshot()
        if hasattr(self.forecaster, "observe"):
            self.forecaster.observe(t, snap.active_jobs + snap.completed_jobs)
        if t - self._last_switch_t < self.min_dwell_min:
            return None

        n_inf = float(snap.inference_jobs)
        w_inf = snap.inference_backlog_1g_min
        n_trn = float(snap.training_jobs)
        w_trn = snap.training_backlog_1g_min
        # the eval throttle bounds lookahead cost (decision events arrive
        # with every job), but a queue jump since the last evaluation is a
        # burst the controller must see immediately
        queue_jumped = abs((n_inf + n_trn) - self._last_eval_n) >= 2.0
        if t - self._last_eval_t < self.eval_interval_min and not queue_jumped:
            return None
        self._last_eval_t = t
        self._last_eval_n = n_inf + n_trn
        current = snap.config_id

        best, costs = self._best_config(t, n_inf, w_inf, n_trn, w_trn, current)
        if best == current:
            # the want lapsed: a later re-wanted switch must open a fresh
            # deferral window, not inherit a stale _defer_since
            self._defer_target = None
            return None
        if current not in costs:
            # the running layout is outside the candidate set (an
            # ``initial_config`` override): adopt the lookahead winner
            # immediately — there is no priced incumbent to defend
            self._defer_target = None
            self._last_switch_t = t
            return best
        improvement = costs[current] - costs[best]
        shrinking = self.configs[best].num_slices < self.configs[current].num_slices
        margin = self.downsize_margin if shrinking else self.switch_margin
        if improvement <= margin * max(abs(costs[current]), 1e-9):
            self._defer_target = None
            return None
        if self.repartition_mode == "partial":
            # opportunistic switch timing: if the transition would tear down
            # a slice instance with a job still running on it, defer — the
            # next completions open displacement-free instants within
            # minutes, and a partial reconfiguration at such an instant
            # preempts nothing.  Bounded by max_defer_min so a long
            # training job cannot pin a stale layout indefinitely.
            plan = transition(self.configs[current], self.configs[best])
            surviving = {i for i, _ in plan.surviving}
            if any(s not in surviving for s in snap.occupied_slices):
                if self._defer_target != best:
                    self._defer_target = best
                    self._defer_since = t
                if t - self._defer_since < self.max_defer_min:
                    return None
        self._defer_target = None
        self._last_switch_t = t
        return best

    def next_timer(self, t: float) -> Optional[float]:
        return t + self.reconsider_min

    def reset(self) -> None:
        """Clear episode state (dwell/eval clocks, forecaster bias)."""
        self._last_eval_t = -math.inf
        self._last_eval_n = 0.0
        self._last_switch_t = -math.inf
        self._defer_target = None
        self._defer_since = 0.0
        if hasattr(self.forecaster, "reset"):
            self.forecaster.reset()

    # ------------------------------------------------------------------
    # fluid lookahead

    def _expected_lateness(self, config_id: int, wait: float) -> float:
        """Mean lateness (min) of an arrival facing ``wait`` min of queue."""
        headrooms, prefix = self._late[config_id]
        k = bisect.bisect_left(headrooms, wait)
        if k == 0:
            return 0.0
        return (k * wait - prefix[k]) / len(headrooms)

    def _best_config(
        self,
        t: float,
        n_inf: float,
        w_inf: float,
        n_trn: float,
        w_trn: float,
        current: Optional[int],
    ) -> Tuple[int, Dict[int, float]]:
        # State-adaptive horizon (shared by every candidate so costs stay
        # comparable): the controller re-optimizes at the next decision
        # event, so committing a near-empty system to a 30-minute rollout
        # overprices coarse configs it would abandon two arrivals later —
        # the effective commitment is roughly the time to the next couple
        # of arrivals plus the current drain, clamped to the full horizon.
        lam0 = max(self.forecaster.rate(t), 1e-3)
        drain = (w_inf + w_trn) / self._ref_capacity
        horizon = min(self.horizon_min, max(6.0, 2.0 / lam0 + drain))
        costs = {
            cid: self._predict_cost(
                cid, t, n_inf, w_inf, n_trn, w_trn,
                switch=(cid != current), horizon_min=horizon,
                survive_frac=self._survive_frac(current, cid),
            )
            for cid in self.configs
        }
        best = min(costs, key=lambda cid: (costs[cid], cid))
        return best, costs

    def _survive_frac(self, current: Optional[int], cand: int) -> float:
        """Fraction of the incumbent's slot capacity that survives a switch
        to ``cand`` (0 under drain mode, for an unknown incumbent, or full
        turnover) — what makes the lookahead price a *partial* transition
        instead of the flat full-drain stall."""
        if (
            self.repartition_mode != "partial"
            or current is None
            or current == cand
            or current not in self.configs
        ):
            return 0.0
        key = (current, cand)
        frac = self._surv_frac_cache.get(key)
        if frac is None:
            old = self.configs[current]
            plan = transition(old, self.configs[cand])
            surviving_slots = sum(old.slices[i].slots for i, _ in plan.surviving)
            frac = surviving_slots / max(old.total_slots, 1)
            self._surv_frac_cache[key] = frac
        return frac

    def _predict_cost(
        self,
        config_id: int,
        t0: float,
        n_inf: float,
        w_inf: float,
        n_trn: float,
        w_trn: float,
        switch: bool,
        horizon_min: Optional[float] = None,
        survive_frac: float = 0.0,
    ) -> float:
        """Predicted ET of running ``config_id`` over the lookahead horizon.

        ``survive_frac`` is the slot-capacity fraction that survives the
        transition into ``config_id`` (partial repartitioning): during the
        §IV-D-3 stall the candidate keeps serving at that fraction of its
        occupancy-appropriate rate, and only the displaced ``1 -
        survive_frac`` share of in-flight work pays the upfront requeue
        wait.  ``0.0`` reproduces the flat full-drain pricing exactly.
        """
        if horizon_min is None:
            horizon_min = self.horizon_min
        srv_table = self._srv[config_id]
        pwr_table = self._pwr[config_id]
        num_slices = len(srv_table) - 1
        mu_full = srv_table[-1]
        p_inf = self.inference_split
        rate = self.forecaster.rate
        mu_per_server = self._mu_server[config_id]
        mg_factor = self._mg_factor[config_id]
        # stochastic-wait cap: past this the fluid backlog term carries the
        # overload signal, so the Erlang term must not double it unboundedly
        wq_cap = self.horizon_min

        ni, wi, nt, wt = n_inf, w_inf, n_trn, w_trn
        energy_wh = 0.0
        tard_job_min = 0.0
        arrived = 0.0
        t = t0
        remaining = horizon_min
        # jobs already in the system are charged their expected lateness up
        # front — the burst signal that makes the controller react to a
        # queue spike instead of only pricing future arrivals
        if ni + nt > 1e-9:
            # jobs already in the system split into two populations across a
            # switch: runners on *surviving* slice instances keep going and
            # only face the backlog drain, while displaced runners and the
            # queue requeue behind the stall and eat the full penalty.  The
            # lateness curve prices the 4 s slip marginally — at a quiet
            # moment every job has headroom and the term vanishes (the
            # nightly consolidation to the full GPU stays free), under load
            # tearing through a busy layout costs real predicted lateness.
            # survive_frac = 0 (drain pricing / full turnover) collapses to
            # the legacy flat full-drain charge, bit for bit.
            n_tot0 = ni + nt
            base_wait = (wi + wt) / mu_full
            if switch:
                surv_jobs = survive_frac * min(n_tot0, float(num_slices))
                tard_job_min += surv_jobs * self._expected_lateness(
                    config_id, base_wait
                )
                tard_job_min += (n_tot0 - surv_jobs) * self._expected_lateness(
                    config_id, base_wait + self.penalty_min
                )
            else:
                tard_job_min += n_tot0 * self._expected_lateness(config_id, base_wait)
        # a switching candidate starts with the repartition stall: arrivals
        # queue and only the transition's surviving capacity keeps serving
        # (none of it under drain mode — the GPU idles, §IV-D-3)
        blocked = self.penalty_min if switch else 0.0
        while remaining > 1e-9:
            dt = min(self.step_min, remaining)
            lam = rate(t)
            n_tot = ni + nt
            if blocked > 0.0:
                dt = min(dt, blocked)
                # occupancy scaled to the surviving capacity fraction: a
                # partial transition serves (and draws power) at the
                # surviving slices' share of the normal rate
                x = min(n_tot, float(num_slices)) * survive_frac
                k_lo = min(int(x), num_slices - 1) if num_slices else 0
                frac = x - k_lo
                srv_total = srv_table[k_lo] + frac * (srv_table[k_lo + 1] - srv_table[k_lo])
                watts = pwr_table[k_lo] + frac * (pwr_table[k_lo + 1] - pwr_table[k_lo])
                srv_t = srv_total * (nt / n_tot) if n_tot > 1e-12 else 0.0
                srv_i = srv_total - srv_t
                blocked -= dt
            else:
                # continuous occupancy: k_lo seats fully busy, one more busy
                # ``frac`` of the time — service and power interpolate over
                # occupancy *levels* (duty cycle), not over busy slots
                x = min(n_tot, float(num_slices))
                k_lo = min(int(x), num_slices - 1) if num_slices else 0
                frac = x - k_lo
                srv_total = srv_table[k_lo] + frac * (srv_table[k_lo + 1] - srv_table[k_lo])
                watts = pwr_table[k_lo] + frac * (pwr_table[k_lo + 1] - pwr_table[k_lo])
                # processor-sharing split of the pooled rate by job count
                srv_t = srv_total * (nt / n_tot) if n_tot > 1e-12 else 0.0
                srv_i = srv_total - srv_t
            served_i = min(wi, srv_i * dt)
            served_t = min(wt, srv_t * dt)
            # completions deplete job counts at the observed mean remaining
            # work per job, so half-done jobs finish at the right rate
            if wi > 1e-9 and ni > 1e-9:
                ni = max(ni - served_i * ni / wi, 0.0)
            wi -= served_i
            if wt > 1e-9 and nt > 1e-9:
                nt = max(nt - served_t * nt / wt, 0.0)
            wt -= served_t
            arr = lam * dt
            ni += arr * p_inf
            wi += arr * p_inf * self.mean_work_inf
            nt += arr * (1.0 - p_inf)
            wt += arr * (1.0 - p_inf) * self.mean_work_trn
            energy_wh += watts * dt / 60.0
            # expected lateness of this step's arrivals: fluid backlog
            # drain plus the stochastic M/M/c wait, priced through the
            # config's sampled lateness curve
            # The slices run *preemptive EDF*: an urgent arrival displaces a
            # long job instantly, so an underloaded deadline scheduler
            # misses (almost) nothing regardless of FCFS wait — the
            # stochastic term only ramps in as utilization approaches
            # saturation, scaled further by the heavy-tail (1+CV^2)/2
            # M/G/c correction.  The fluid backlog term stays unscaled: an
            # actual queue is actual lateness risk at any utilization.
            rho = min(lam / (num_slices * mu_per_server), 1.0) if mu_per_server else 1.0
            edf_scale = min(max((rho - 0.25) / 0.5, 0.0), 1.0)
            factor = 1.0 + (mg_factor - 1.0) * rho
            wait = (wi + wt) / mu_full + min(
                edf_scale * factor * erlang_c_wait(num_slices, lam, mu_per_server),
                wq_cap,
            )
            tard_job_min += arr * self._expected_lateness(config_id, wait)
            arrived += arr
            t += dt
            remaining -= dt
        jobs_seen = max(n_inf + n_trn + arrived, 1.0)
        avg_tardiness = tard_job_min / jobs_seen
        a = self.et_alpha
        return (a * energy_wh + avg_tardiness) / (a + 1.0)


def device_forecast_factory(forecaster_factory=None, **policy_kwargs):
    """Per-device ``(index, profile) -> ForecastPolicy`` fleet factory.

    Builds a *native* forecast controller for every fleet member — candidate
    configurations and the power curve come from the device's own
    :class:`~repro.fleet.devices.DeviceProfile`, so an A30 evaluates its own
    four layouts instead of having A100-space choices translated after the
    fact.  ``forecaster_factory()`` supplies a fresh forecaster per device
    (policies and their EWMA state must never be shared across devices);
    ``None`` gives each device the default paper-diurnal day model.
    """

    def factory(index: int, profile) -> ForecastPolicy:
        forecaster = forecaster_factory() if forecaster_factory is not None else None
        return ForecastPolicy(
            forecaster=forecaster,
            configs=profile.configs,
            power=profile.power,
            **policy_kwargs,
        )

    return factory
