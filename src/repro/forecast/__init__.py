"""Predictive repartitioning: arrival forecasting + model-predictive control.

The paper closes on the observation that preferred MIG configurations recur
at specific times of day, "suggesting a policy for predictive and automatic
reconfiguration" (§V-C, Fig. 11).  This package implements that conjectured
policy family as a measurable baseline:

* :mod:`repro.forecast.forecaster` — arrival-rate forecasting: a diurnal
  Fourier day-model fitted by least squares on any registered scenario's
  arrival stream (:func:`fit_scenario_forecaster`), corrected online by an
  EWMA bias tracker that watches realized arrivals during the simulated day;
* :mod:`repro.forecast.policy` — :class:`ForecastPolicy`, a model-predictive
  :class:`~repro.core.simulator.RepartitionPolicy`: at each decision event it
  rolls a fluid approximation of the MIG queue forward over a lookahead
  horizon for every candidate configuration, charges the §IV-D-3 repartition
  penalty, and picks the configuration minimizing predicted ET (energy +
  tardiness scalarization), with dwell-time and improvement-margin hysteresis
  so the 4 s penalty always amortizes.

The policy is registered as ``"forecast"`` in the sweep policy registry
(:data:`repro.sweep.cells.POLICIES`), compared against the other policy
families by the ``repartition_policies`` grid, usable per-device inside a
fleet (natively via :func:`device_forecast_factory`, or through
:class:`repro.fleet.DeviceAdaptedPolicy` translation on non-A100 tables),
and accepted as a ``train_dqn(guide=...)`` demonstration policy to
warm-start the DQN.  See EXPERIMENTS.md §Predictive-controller for measured
results and docs/ARCHITECTURE.md for where the layer sits.
"""

from repro.forecast.forecaster import (
    ArrivalForecaster,
    EWMABiasTracker,
    FourierDayModel,
    fit_fourier_day_model,
    fit_scenario_forecaster,
)
from repro.forecast.policy import (
    EFFECTIVE_THROUGHPUT,
    ForecastPolicy,
    device_forecast_factory,
    expected_throughput,
)

__all__ = [
    "ArrivalForecaster",
    "EWMABiasTracker",
    "FourierDayModel",
    "fit_fourier_day_model",
    "fit_scenario_forecaster",
    "EFFECTIVE_THROUGHPUT",
    "ForecastPolicy",
    "device_forecast_factory",
    "expected_throughput",
]
