"""Roofline-derived job elasticity (the paper's Fig. 2 from first principles).

For a job running on a sub-mesh of ``k``/7 of the pod:

* compute and HBM terms scale ~1/k (more chips, same work),
* the collective term *degrades* slowly with k (bigger rings, longer paths):
  modelled as ``Tcoll * (1 + alpha*log2(k))``,
* shardability caps k: a decode batch of 1 row or 4 attention heads cannot
  use 7 slots productively (cap -> the paper's "capped" class).

``arch_elasticity`` loads per-(arch x shape) roofline terms from the dry-run
artifacts when available and falls back to the analytic FLOPs model, then
returns a normalized throughput curve tp(k) with tp(1)=1 — exactly the
object the paper draws synthetically (§V-A).
"""

from __future__ import annotations

import glob
import json
import math
import os
from functools import lru_cache
from typing import Dict, Optional, Tuple

from repro.analysis.constants import CHIP_FLOPS_BF16, HBM_BW, LINK_BW
from repro.configs import get_config
from repro.core.jobs import Elasticity, ElasticityClass
from repro.launch.shapes import SHAPES

__all__ = ["service_minutes", "arch_elasticity", "classify_elasticity"]

CHIPS_PER_SLOT = 256 // 7  # ~36 chips per "slot"
COLL_ALPHA = 0.35  # collective degradation per log2(slots)


def _dryrun_record(arch: str, shape: str) -> Optional[Dict]:
    base = os.path.join(
        os.path.dirname(__file__), "..", "..", "..", "artifacts", "dryrun"
    )
    path = os.path.abspath(os.path.join(base, f"{arch}__{shape}__pod.json"))
    if os.path.exists(path):
        with open(path) as f:
            rec = json.load(f)
        if rec.get("ok") and rec.get("cost"):
            return rec
    return None


def _analytic_terms(arch: str, shape: str) -> Tuple[float, float, float]:
    """(compute_s, memory_s, collective_s) on the FULL pod, analytic fallback."""
    cfg = get_config(arch)
    sh = SHAPES[shape]
    n_params = cfg.param_count(active_only=True)
    chips = 256
    if sh.kind == "train":
        flops = 6.0 * n_params * sh.global_batch * sh.seq_len
        bytes_ = 3 * 2.0 * cfg.param_count() + sh.global_batch * sh.seq_len * cfg.d_model * 2 * cfg.n_layers
        coll = 2.0 * 2 * cfg.param_count()  # grad all-reduce, bf16 ring
    elif sh.kind == "prefill":
        flops = 2.0 * n_params * sh.global_batch * sh.seq_len
        bytes_ = 2.0 * cfg.param_count() + sh.global_batch * sh.seq_len * cfg.d_model * 2 * cfg.n_layers
        coll = 0.3 * 2 * cfg.param_count()
    else:  # decode: one token per request
        flops = 2.0 * n_params * sh.global_batch
        kv = (
            cfg.n_layers * sh.global_batch * min(sh.seq_len, cfg.sliding_window or sh.seq_len)
            * cfg.n_kv_heads * cfg.resolved_head_dim * 2 * 2
        )
        bytes_ = 2.0 * cfg.param_count() + kv
        coll = 0.1 * 2 * cfg.param_count()
    return (
        flops / (chips * CHIP_FLOPS_BF16),
        bytes_ / (chips * HBM_BW),
        coll / (chips * LINK_BW),
    )


@lru_cache(maxsize=None)
def _terms(arch: str, shape: str) -> Tuple[float, float, float]:
    rec = _dryrun_record(arch, shape)
    if rec is not None:
        comp = rec["cost"]["composite"]
        chips = rec.get("devices", 256)
        flops = comp["flops"] * chips  # per-device -> total
        bytes_ = comp["bytes_accessed"] * chips
        coll = sum(comp["collectives"].values()) * chips
        return (
            flops / (chips * CHIP_FLOPS_BF16),
            bytes_ / (chips * HBM_BW),
            coll / (chips * LINK_BW),
        )
    return _analytic_terms(arch, shape)


def _max_parallel_slots(arch: str, shape: str) -> int:
    """Shardability cap in slots (1..7)."""
    cfg = get_config(arch)
    sh = SHAPES[shape]
    if sh.kind == "decode":
        # parallelism: batch rows x kv-groups x (seq for attention caches)
        par = sh.global_batch * max(cfg.n_kv_heads, 1)
        if cfg.block_pattern in ("xlstm",):
            par = sh.global_batch * max(cfg.d_model // 128, 1)
        chips = min(par, 256)
    elif sh.kind == "prefill":
        chips = min(sh.global_batch * sh.seq_len // 2048, 256)
    else:
        chips = 256
    # small models also cap on useful TP width
    tp_cap = max(cfg.d_model // 256, 1) * max(cfg.n_heads, 1)
    chips = min(chips, tp_cap * 8)
    return max(1, min(7, round(chips / CHIPS_PER_SLOT) or 1))


def service_minutes(arch: str, shape: str, slots: float) -> float:
    """Wall-clock minutes for one job quantum on ``slots``/7 of the pod."""
    tc, tm, tcoll = _terms(arch, shape)
    k = max(min(slots, 7.0), 1e-6)
    kcap = float(_max_parallel_slots(arch, shape))
    ke = min(k, kcap)  # beyond the cap, extra slots do nothing
    t = max(
        tc * 7.0 / ke,
        tm * 7.0 / ke,
        tcoll * (1.0 + COLL_ALPHA * math.log2(max(ke, 1.0))) * 7.0 / ke if tcoll else 0.0,
    )
    quanta = _JOB_QUANTA.get(shape, 1.0)
    return max(t, 1e-9) * quanta / 60.0


# one "job" = this many step/request quanta (sized so jobs land in the
# paper's §V-A duration regime: inference ~minutes, training ~tens of min)
_JOB_QUANTA = {
    "train_4k": 200.0,  # 200 training steps (fine-tuning burst)
    "prefill_32k": 2_000.0,  # batched prefill session
    "decode_32k": 200_000.0,  # serving session: 200k decode steps
    "long_500k": 100_000.0,
}


def arch_elasticity(arch: str, shape: str) -> Elasticity:
    """Normalized throughput curve tp(k), tp(1)=1, from the roofline model."""
    t1 = service_minutes(arch, shape, 1)

    def tp(k: float) -> float:
        return t1 / service_minutes(arch, shape, k)

    label = f"{arch}:{shape}"
    return Elasticity(classify_elasticity(tp), label, tp)


def classify_elasticity(tp) -> ElasticityClass:
    """Map a tp curve onto the paper's three classes (Fig. 2)."""
    t7 = tp(7.0)
    t4 = tp(4.0)
    if t7 >= 6.0:
        return ElasticityClass.LINEAR
    if t7 - t4 < 0.25:  # flat after mid-size: capped
        return ElasticityClass.CAPPED
    return ElasticityClass.SUBLINEAR
