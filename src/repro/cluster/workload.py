"""Cluster workload: diurnal arrivals of (arch x shape) jobs on the pod.

Same §V-A arrival process as the paper layer, but per-job attributes come
from the real substrate: the job's elasticity is the roofline-derived curve
of its (arch, shape) and its work is the service time of its quantum count
on a 1-slot sub-mesh.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.cluster.elasticity import arch_elasticity, service_minutes
from repro.core.jobs import Job, JobKind
from repro.core.workload import WorkloadSpec, _sample_arrivals

__all__ = ["ClusterWorkloadSpec", "generate_cluster_jobs", "DEFAULT_MIX"]

# (arch, shape, weight, kind): a serving-heavy mix with fine-tuning bursts —
# mirrors the paper's 80/20 inference/training split.
DEFAULT_MIX: Sequence[Tuple[str, str, float, JobKind]] = (
    ("gemma3-1b", "decode_32k", 0.22, JobKind.INFERENCE),
    ("gemma3-12b", "decode_32k", 0.12, JobKind.INFERENCE),
    ("mixtral-8x7b", "decode_32k", 0.12, JobKind.INFERENCE),
    ("xlstm-350m", "decode_32k", 0.10, JobKind.INFERENCE),
    ("whisper-base", "decode_32k", 0.08, JobKind.INFERENCE),
    ("phi-3-vision-4.2b", "prefill_32k", 0.08, JobKind.INFERENCE),
    ("jamba-v0.1-52b", "long_500k", 0.08, JobKind.INFERENCE),
    ("gemma3-1b", "train_4k", 0.07, JobKind.TRAINING),
    ("stablelm-3b", "train_4k", 0.06, JobKind.TRAINING),
    ("granite-moe-3b-a800m", "train_4k", 0.05, JobKind.TRAINING),
    ("mixtral-8x7b", "train_4k", 0.02, JobKind.TRAINING),
)


@dataclasses.dataclass(frozen=True)
class ClusterWorkloadSpec:
    horizon_min: float = 24 * 60.0
    constant_rate: Optional[float] = None
    mix: Sequence[Tuple[str, str, float, JobKind]] = DEFAULT_MIX
    slack_lo: float = 1.2
    slack_hi: float = 4.0
    work_scale: float = 1.0  # scales job quanta (load knob)

    def as_core_spec(self) -> WorkloadSpec:
        return WorkloadSpec(
            horizon_min=self.horizon_min, constant_rate=self.constant_rate
        )


def generate_cluster_jobs(
    spec: ClusterWorkloadSpec, seed: int
) -> List[Job]:
    rng = np.random.default_rng(seed)
    arrivals = _sample_arrivals(spec.as_core_spec(), rng)
    weights = np.asarray([m[2] for m in spec.mix], np.float64)
    weights = weights / weights.sum()
    jobs: List[Job] = []
    for i, t in enumerate(arrivals):
        arch, shape, _, kind = spec.mix[int(rng.choice(len(spec.mix), p=weights))]
        elast = arch_elasticity(arch, shape)
        # work = 1-slot service time of the job quantum, jittered 0.5-1.5x
        work = service_minutes(arch, shape, 1) * spec.work_scale
        work *= rng.uniform(0.5, 1.5)
        work = float(np.clip(work, 1.0 / 60.0, 240.0))
        slack = rng.uniform(spec.slack_lo, spec.slack_hi)
        deadline = t + slack * elast.duration(work, 7)
        jobs.append(
            Job(
                job_id=i,
                kind=kind,
                arrival=t,
                work=work,
                deadline=deadline,
                elasticity=elast,
            )
        )
    return jobs
