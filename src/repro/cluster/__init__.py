"""TPU-cluster adaptation of the paper (DESIGN.md §2).

The pod (16x16 = 256 chips) is partitioned into the paper's 12 slice
profiles ("slots" of 36 chips; a 7g slice = the full pod's compute pool).
Jobs are train/prefill/decode invocations of the 10 assigned architectures;
their throughput elasticity across slice sizes is *derived from the dry-run
roofline terms* instead of drawn from synthetic distributions — reproducing
the paper's key premise (mixed linear/capped/sublinear workloads) from first
principles.
"""

from repro.cluster.elasticity import (
    arch_elasticity,
    classify_elasticity,
    service_minutes,
)
from repro.cluster.workload import ClusterWorkloadSpec, generate_cluster_jobs

__all__ = [
    "arch_elasticity",
    "classify_elasticity",
    "service_minutes",
    "ClusterWorkloadSpec",
    "generate_cluster_jobs",
]
