"""Predictive repartitioning walkthrough: forecast + MPC over a simulated day.

    PYTHONPATH=src python examples/predictive_day.py [--seeds 8] [--scenario paper-diurnal]

1. fits the diurnal Fourier day-model on training days of the scenario and
   prints it against the Fig. 5 ground truth;
2. runs one day under the predictive ForecastPolicy and prints the
   configuration timeline it chose (the paper's closing conjecture —
   "specific preferred configurations at different times of the day" —
   made executable);
3. compares ForecastPolicy against NoMIG / Static / DayNight / queue
   heuristic on the ET metric over ``--seeds`` evaluation days;
4. optionally warm-starts a small DQN from the controller
   (``--warm-start-episodes N`` — the ``train_dqn(guide=...)`` hook).
"""

import argparse

from repro.core.metrics import et_table
from repro.core.scenarios import generate_scenario
from repro.core.schedulers import make_scheduler
from repro.core.simulator import DayNightPolicy, MIGSimulator, NoMIGPolicy, StaticPolicy
from repro.core.workload import arrival_rate
from repro.forecast import ArrivalForecaster, ForecastPolicy, fit_scenario_forecaster
from repro.launch.cluster_sim import queue_heuristic_policy


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--scenario", default="paper-diurnal")
    ap.add_argument("--seeds", type=int, default=8, help="evaluation days per policy")
    ap.add_argument("--train-seeds", type=int, default=8, help="days the forecaster fits on")
    ap.add_argument("--warm-start-episodes", type=int, default=0,
                    help="also train a DQN for N episodes guided by the controller")
    args = ap.parse_args()

    # 1. fit the day model ------------------------------------------------
    model = fit_scenario_forecaster(scenario=args.scenario, train_seeds=args.train_seeds)
    print(f"Fourier day-model ({model.harmonics} harmonics) vs Fig. 5 pattern:")
    for h in range(0, 24, 3):
        print(f"  {h:02d}:00  fitted {model.rate(h * 60.0):.3f} jobs/min"
              f"   true {arrival_rate(h * 60.0):.3f}")

    # 2. one predictive day ----------------------------------------------
    sim = MIGSimulator(make_scheduler("EDF-SS"))
    policy = ForecastPolicy(ArrivalForecaster(model))
    res = sim.run(generate_scenario(args.scenario, seed=0), policy=policy)
    print(f"\nConfig timeline (seed 0, {res.repartitions} repartitions):")
    for t, cfg in sim.config_trace:
        print(f"  {int(t) // 60:02d}:{int(t) % 60:02d}  -> config {cfg}")

    # 3. policy-family comparison ----------------------------------------
    def run_days(policy_factory, mig_enabled=True):
        out = []
        for k in range(args.seeds):
            s = MIGSimulator(make_scheduler("EDF-SS"), mig_enabled=mig_enabled)
            out.append(s.run(generate_scenario(args.scenario, seed=10_000 + k),
                             policy=policy_factory()))
        return out

    per = {
        # NoMIG disables MIG so linear jobs get the §V-A 6 % full-GPU
        # speedup — same definition as the repartition_policies grid
        "NoMIG": run_days(NoMIGPolicy, mig_enabled=False),
        "StaticMIG": run_days(lambda: StaticPolicy(3)),
        "DayNightMIG": run_days(DayNightPolicy),
        "Heuristic": run_days(queue_heuristic_policy),
        "Forecast": run_days(lambda: ForecastPolicy(ArrivalForecaster(model))),
    }
    table, a = et_table(per)
    print(f"\nET comparison over {args.seeds} days (a={a:.2e}):")
    for name, et in sorted(table.items(), key=lambda kv: kv[1]):
        rs = per[name]
        n = len(rs)
        print(f"  {name:12s} ET={et:8.4f} energy={sum(r.energy_wh for r in rs)/n:7.1f}Wh"
              f" tardiness={sum(r.avg_tardiness for r in rs)/n:6.3f}min"
              f" repartitions={sum(r.repartitions for r in rs)/n:6.1f}")

    # 4. optional: warm-start the DQN from the controller -----------------
    if args.warm_start_episodes > 0:
        from repro.core.rl import train_dqn

        guide = ForecastPolicy(ArrivalForecaster(model))
        learner, stats = train_dqn(
            num_episodes=args.warm_start_episodes,
            guide=guide,
            guide_episodes=max(args.warm_start_episodes // 4, 1),
            scenario=args.scenario,
        )
        tail = stats.episode_rewards[-10:]
        print(f"\nDQN warm-started from the controller: {stats.episodes} episodes,"
              f" final-{len(tail)} reward {sum(tail) / max(len(tail), 1):.2f}")


if __name__ == "__main__":
    main()
