"""The paper's headline experiment end-to-end: train the repartitioning DQN,
then run Table III (Dynamic vs DayNight vs Static vs NoMIG).

    PYTHONPATH=src python examples/dynamic_repartitioning_day.py \
        [--episodes 400] [--eval-iterations 20] [--backend host|batched]

Short trainings underperform; EXPERIMENTS.md used 900+ episodes.
``--backend batched`` trains with the fused on-device scan
(repro.core.rl.batched_train): EDF-FS, fixed 15-min decision cadence,
orders of magnitude more env-steps/sec (scripts/bench_rl.py measures it).
"""

import argparse

from repro.core.metrics import et_table
from repro.core.rl import evaluate_policy, greedy_policy, train_dqn
from repro.core.rl.dqn import DQNConfig
from repro.core.rl.env import FEATURE_DIM
from repro.core.simulator import DayNightPolicy, NoMIGPolicy, StaticPolicy
from repro.launch.cluster_sim import queue_heuristic_policy


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--episodes", type=int, default=400)
    ap.add_argument("--eval-iterations", type=int, default=20)
    ap.add_argument("--save", default=None)
    ap.add_argument("--backend", choices=("host", "batched"), default="host")
    args = ap.parse_args()

    cfg = DQNConfig(
        state_dim=FEATURE_DIM,
        eps_decay_episodes=max(args.episodes // 2, 1),
        n_step=8,
        lr=3e-4,
        target_sync_every=2000,
    )
    if args.backend == "batched":
        learner, stats = train_dqn(
            num_episodes=args.episodes,
            dqn_config=cfg,
            verbose=True,
            backend="batched",
            scheduler_name="EDF-FS",
        )
        print(
            f"batched training: {stats.env_steps} env steps in "
            f"{stats.wall_seconds:.1f}s ({stats.env_steps_per_sec:.0f}/s)"
        )
    else:
        learner, stats = train_dqn(
            num_episodes=args.episodes,
            dqn_config=cfg,
            verbose=True,
            guide=queue_heuristic_policy(),
            guide_episodes=max(args.episodes // 10, 10),
        )
    if args.save:
        learner.save(args.save)

    per = {
        "NoMIG": evaluate_policy(
            NoMIGPolicy, num_iterations=args.eval_iterations, mig_enabled=False
        ),
        "StaticMIG": evaluate_policy(
            lambda: StaticPolicy(3), num_iterations=args.eval_iterations
        ),
        "DayNightMIG": evaluate_policy(
            DayNightPolicy, num_iterations=args.eval_iterations
        ),
        # cadence-trained policies evaluate on the same 15-min cadence
        "DynamicMIG(DQN)": evaluate_policy(
            lambda: greedy_policy(
                learner,
                decision_interval_min=(
                    15.0 if args.backend == "batched" else None
                ),
            ),
            num_iterations=args.eval_iterations,
        ),
    }
    table, a = et_table(per)
    print(f"\nTable III (a={a:.2e}):")
    for k, v in sorted(table.items(), key=lambda kv: kv[1]):
        rs = per[k]
        n = len(rs)
        print(
            f"  {k:16s} ET={v:7.3f} energy={sum(r.energy_wh for r in rs)/n:7.1f}Wh "
            f"tardiness={sum(r.avg_tardiness for r in rs)/n:6.3f}min "
            f"repartitions={sum(r.repartitions for r in rs)/n:6.1f}"
        )


if __name__ == "__main__":
    main()
