"""End-to-end training driver: a ~25M-param gemma3-family model for a few
hundred steps on CPU, with checkpoint/resume.

    PYTHONPATH=src python examples/train_tiny_lm.py [--steps 300]

Exercises the full substrate: synthetic pipeline -> pjit'd train step (layer
scan + remat) -> AdamW + cosine schedule -> async checkpoints. The loss curve
must drop (asserted).
"""

import argparse
import dataclasses
import tempfile

import numpy as np

from repro.configs import smoke_config
from repro.launch.train import train


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--arch", default="gemma3_1b")
    args = ap.parse_args()

    with tempfile.TemporaryDirectory() as ckpt:
        _, losses = train(
            args.arch,
            steps=args.steps,
            smoke=True,
            global_batch=8,
            seq_len=256,
            lr=1e-3,
            ckpt_dir=ckpt,
            ckpt_every=100,
            log_every=20,
        )
    n = max(len(losses) // 10, 1)
    first, last = float(np.mean(losses[:n])), float(np.mean(losses[-n:]))
    print(f"loss: {first:.3f} -> {last:.3f}")
    assert last < first - 0.5, "training did not reduce the loss"
    print("OK: loss decreased")


if __name__ == "__main__":
    main()
