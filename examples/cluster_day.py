"""Cluster-day demo: the paper's scheduler running a TPU pod serving the 10
assigned architectures, with failure injection.

    PYTHONPATH=src python examples/cluster_day.py [--failures]
"""

import argparse

from repro.core.metrics import et_table
from repro.core.simulator import DayNightPolicy, StaticPolicy
from repro.distributed.fault_tolerance import FailureModel
from repro.launch.cluster_sim import queue_heuristic_policy, run_days


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--iterations", type=int, default=5)
    ap.add_argument("--failures", action="store_true")
    args = ap.parse_args()

    per = {
        "static": run_days(lambda: StaticPolicy(3), iterations=args.iterations),
        "daynight": run_days(DayNightPolicy, iterations=args.iterations),
        "dynamic": run_days(queue_heuristic_policy, iterations=args.iterations),
    }
    table, _ = et_table(per)
    print("TPU pod, diurnal (arch x shape) job mix:")
    for k, v in sorted(table.items(), key=lambda kv: kv[1]):
        rs = per[k]
        n = len(rs)
        print(
            f"  {k:9s} ET={v:9.3f} energy={sum(r.energy_wh for r in rs)/n/1000:7.1f}kWh/day "
            f"tardiness={sum(r.avg_tardiness for r in rs)/n:7.3f}min "
            f"repartitions={sum(r.repartitions for r in rs)/n:6.1f}"
        )
    if args.failures:
        fm = FailureModel(mtbf_minutes=12 * 60.0, seed=7)
        rs = run_days(queue_heuristic_policy, iterations=args.iterations, failures=fm)
        n = len(rs)
        print(
            f"  with slice failures (MTBF 12h): "
            f"tardiness={sum(r.avg_tardiness for r in rs)/n:7.3f}min "
            f"(jobs all complete: {all(r.num_jobs > 0 for r in rs)})"
        )


if __name__ == "__main__":
    main()
