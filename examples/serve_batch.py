"""Batched serving: prefill a prompt batch, then decode with KV caches.

    PYTHONPATH=src python examples/serve_batch.py [--tokens 32]

Uses the mixtral-family smoke config (MoE + sliding-window ring-buffer
caches) — the serving path the ``decode_*`` dry-run shapes lower at scale.
"""

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import smoke_config
from repro.models import decode_step, forward, init_cache, init_params


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--tokens", type=int, default=32)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--arch", default="mixtral_8x7b")
    args = ap.parse_args()

    cfg = smoke_config(args.arch)
    params = init_params(cfg, seed=0)
    rng = np.random.default_rng(0)
    B, prompt_len = args.batch, 16
    prompt = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, prompt_len)), jnp.int32)

    # prefill: feed the prompt through decode steps to build the cache
    cache = init_cache(cfg, B, prompt_len + args.tokens)
    step = jax.jit(lambda p, c, t, i: decode_step(cfg, p, c, t, i, impl="ref"))
    tok = prompt[:, :1]
    t0 = time.time()
    for i in range(prompt_len):
        logits, cache = step(params, cache, prompt[:, i : i + 1], jnp.asarray(i, jnp.int32))
    # greedy decode
    out_tokens = []
    tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
    for i in range(prompt_len, prompt_len + args.tokens):
        out_tokens.append(np.asarray(tok[:, 0]))
        logits, cache = step(params, cache, tok, jnp.asarray(i, jnp.int32))
        tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
    dt = time.time() - t0
    gen = np.stack(out_tokens, axis=1)
    print(f"generated {gen.shape} tokens in {dt:.2f}s "
          f"({B * args.tokens / dt:.1f} tok/s on CPU smoke config)")
    print("sample row:", gen[0][:16])
    assert gen.shape == (B, args.tokens)
    assert not np.any(np.isnan(np.asarray(logits, np.float32)))
    print("OK")


if __name__ == "__main__":
    main()
