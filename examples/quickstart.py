"""Quickstart: the paper in 60 seconds.

Simulates one diurnal day on an A100-40GB under four policies and prints the
Table-III-style comparison, then shows the in-configuration scheduler ranking
(Table II, reduced basket).

    PYTHONPATH=src python examples/quickstart.py
"""

from repro.core import (
    DayNightPolicy,
    MIGSimulator,
    NoMIGPolicy,
    StaticPolicy,
    WorkloadSpec,
    et_table,
    generate_jobs,
    make_scheduler,
)
from repro.launch.cluster_sim import queue_heuristic_policy


def main() -> None:
    spec = WorkloadSpec()  # §V-A diurnal day, 80% inference

    print("=== Dynamic repartitioning vs benchmarks (Table III style) ===")
    per = {}
    for name, factory, mig in [
        ("NoMIG", NoMIGPolicy, False),
        ("StaticMIG(cfg3)", lambda: StaticPolicy(3), True),
        ("DayNightMIG", DayNightPolicy, True),
        ("DynamicMIG", queue_heuristic_policy, True),
    ]:
        sim = MIGSimulator(make_scheduler("EDF-SS"), mig_enabled=mig)
        per[name] = [
            sim.run(generate_jobs(spec, seed=s), policy=factory()) for s in range(4)
        ]
    table, a = et_table(per)
    for k, v in sorted(table.items(), key=lambda kv: kv[1]):
        rs = per[k]
        print(
            f"  {k:16s} ET={v:7.3f}  energy={sum(r.energy_wh for r in rs)/4:7.1f} Wh"
            f"  tardiness={sum(r.avg_tardiness for r in rs)/4:6.3f} min"
            f"  repartitions={sum(r.repartitions for r in rs)/4:5.1f}"
        )

    print("\n=== In-configuration schedulers (Table II style, config 3) ===")
    per = {}
    for name in ("EDF-FS", "EDF-SS", "LLF", "LALF"):
        sim = MIGSimulator(make_scheduler(name))
        per[name] = [
            sim.run(generate_jobs(spec, seed=100 + s), policy=StaticPolicy(3))
            for s in range(3)
        ]
    table, _ = et_table(per)
    for k, v in sorted(table.items(), key=lambda kv: kv[1]):
        print(f"  {k:8s} ET={v:7.3f}  preemptions={sum(r.preemptions for r in per[k])/3:6.1f}")


if __name__ == "__main__":
    main()
