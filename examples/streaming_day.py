"""Online streaming: inject jobs into a *running* simulation engine.

    PYTHONPATH=src python examples/streaming_day.py [--scenario paper-diurnal]
        [--load-scale 0.25] [--seed 0] [--policy heuristic]

The paper's simulator ran one pre-known job list to completion; the
steppable :class:`~repro.core.engine.SimulationEngine` decouples the
producer from the event loop.  This example plays a scenario day as a live
stream — each arrival is ``inject()``-ed only when its time comes, exactly
as an online controller would receive it — and prints queue/partition
telemetry at every simulated hour boundary read off live engine snapshots.
A trace sink counts events per hour on the side.

This is the single-device version of what :class:`repro.fleet.FleetSimulator`
does fleet-wide in online dispatch mode (one engine per device co-advanced
on the merged arrival clock).
"""

import argparse

from repro.core.engine import SimulationEngine
from repro.core.scenarios import generate_scenario
from repro.core.schedulers import make_scheduler
from repro.core.simulator import DayNightPolicy, MIGSimulator
from repro.launch.cluster_sim import queue_heuristic_policy


def make_policy(name: str):
    if name == "heuristic":
        return queue_heuristic_policy()
    if name == "daynight":
        return DayNightPolicy()
    raise SystemExit(f"unknown policy {name!r} (heuristic|daynight)")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--scenario", default="paper-diurnal")
    ap.add_argument("--load-scale", type=float, default=0.25)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--policy", default="heuristic")
    args = ap.parse_args()

    jobs = generate_scenario(
        args.scenario, seed=args.seed, load_scale=args.load_scale
    )
    print(f"streaming {len(jobs)} arrivals of '{args.scenario}' "
          f"(load x{args.load_scale}, seed {args.seed}) under {args.policy}\n")

    hour_events = {"n": 0}

    sim = MIGSimulator(make_scheduler("EDF-SS"))
    engine = SimulationEngine(
        sim,
        policy=make_policy(args.policy),
        stream_open=True,  # arrivals come online, not up front
        trace_sink=lambda ev: hour_events.__setitem__("n", hour_events["n"] + 1),
    )

    print("hour   queue  running  config  backlog(1g-min)  energy(Wh)  events/h")
    next_report = 60.0

    def report():
        s = engine.snapshot().sim
        print(
            f"{int(next_report) // 60:02d}:00  "
            f"{s.queue_depth:5d}  {s.running:7d}  {s.config_id:6d}  "
            f"{s.backlog_1g_min:15.1f}  {s.energy_wh:10.1f}  {hour_events['n']:8d}"
        )
        hour_events["n"] = 0

    for job in jobs:
        # advance the live engine to this arrival, reporting at each
        # crossed hour boundary from the running engine's snapshot
        while next_report <= job.arrival:
            engine.run_until(next_report)
            report()
            next_report += 60.0
        engine.inject(job)
        engine.run_until(job.arrival)
    engine.close_stream()
    while not engine.finished:
        engine.run_until(next_report)
        report()
        next_report += 60.0

    res = engine.result()
    print(
        f"\ndrained at {sim.t:.1f} min: {res.num_jobs} jobs, "
        f"{res.energy_wh:.1f} Wh, avg tardiness {res.avg_tardiness:.3f} min, "
        f"{res.repartitions} repartitions, "
        f"{engine.events_processed} events processed"
    )


if __name__ == "__main__":
    main()
